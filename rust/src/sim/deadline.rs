//! Guaranteed-transmission-time transfer — the paper's Alg. 2, simulated.
//!
//! Sends the first `l` levels with per-level parity `[m_1..m_l]` chosen by
//! the Eq. 12 solver, **without retransmission**; the achieved error bound
//! is whatever level prefix survives. The adaptive variant re-solves
//! Eq. 12 for untransmitted levels when the receiver reports a new λ,
//! with the elapsed time deducted from the deadline (Fig. 5).

use super::loss::LossProcess;
use crate::model::error_model::optimize_deadline_paper;
use crate::model::params::{LevelSchedule, NetParams};

/// Parity policy for the deadline-bound transfer.
#[derive(Debug, Clone)]
pub enum DeadlinePolicy {
    /// Fixed per-level parity (solved once for an assumed λ).
    Static(Vec<usize>),
    /// Alg. 2: re-solve Eq. 12 on each receiver λ update for the levels
    /// not yet fully transmitted, against the remaining deadline.
    Adaptive {
        /// Receiver measurement window `T_W`, seconds (paper: 3 s).
        t_w: f64,
        /// Initial λ estimate for the first solve.
        initial_lambda: f64,
    },
}

/// Outcome of one simulated deadline-bound transfer.
#[derive(Debug, Clone)]
pub struct DeadlineResult {
    /// When the last fragment arrived (or the END notification), seconds.
    pub total_time: f64,
    /// Number of leading levels fully recovered (the usable prefix).
    pub levels_recovered: usize,
    /// Achieved relative L∞ error bound ε_{levels_recovered} (ε_0 = 1).
    pub achieved_eps: f64,
    /// Per-level "fully recovered" flags (true ⇒ every FTG decodable).
    pub level_ok: Vec<bool>,
    /// Fragments sent / lost on the wire.
    pub fragments_sent: u64,
    pub fragments_lost: u64,
    /// λ estimates reported by the receiver (time, λ̂).
    pub lambda_updates: Vec<(f64, f64)>,
    /// Parity plans over time: (level_reached, [m_i..m_l]) per re-solve.
    pub plan_changes: Vec<(usize, Vec<usize>)>,
    /// Levels actually transmitted.
    pub levels_sent: usize,
}

/// Simulate Alg. 2: transfer under deadline `tau`. Returns `None` when no
/// feasible level count exists (deadline too small — the protocol throws).
pub fn run_guaranteed_time(
    loss: &mut dyn LossProcess,
    params: &NetParams,
    sched: &LevelSchedule,
    tau: f64,
    policy: &DeadlinePolicy,
) -> Option<DeadlineResult> {
    let n = params.n;
    let s = params.s as u64;
    let r = params.r;
    let t = params.t;
    let step = 1.0 / r;

    // Initial plan.
    let mut plan: Vec<usize> = match policy {
        DeadlinePolicy::Static(m) => m.clone(),
        DeadlinePolicy::Adaptive { initial_lambda, .. } => {
            let p = NetParams { lambda: *initial_lambda, ..*params };
            optimize_deadline_paper(&p, sched, tau)?.m
        }
    };
    if plan.is_empty() {
        return None;
    }
    let levels_sent = plan.len();

    let mut result = DeadlineResult {
        total_time: 0.0,
        levels_recovered: 0,
        achieved_eps: 1.0,
        level_ok: vec![true; levels_sent],
        fragments_sent: 0,
        fragments_lost: 0,
        lambda_updates: Vec::new(),
        plan_changes: vec![(0, plan.clone())],
        levels_sent,
    };

    let (t_w, adaptive) = match policy {
        DeadlinePolicy::Adaptive { t_w, .. } => (*t_w, true),
        DeadlinePolicy::Static(_) => (f64::INFINITY, false),
    };
    let mut window_start = 0.0f64;
    let mut window_losses = 0u64;
    let mut pending_update: Option<(f64, f64)> = None;
    let mut last_solved_lambda = match policy {
        DeadlinePolicy::Adaptive { initial_lambda, .. } => *initial_lambda,
        _ => 0.0,
    };

    let mut clock = 0.0f64;
    let mut last_arrival = 0.0f64;

    for level in 0..levels_sent {
        let mut bytes_left = sched.sizes[level];
        while bytes_left > 0 {
            // Apply a λ update that has reached the sender: re-plan the
            // remaining levels against the remaining deadline. Already
            // transmitted FTGs are sunk; the current level's remaining
            // bytes are re-planned too (its m_i can change mid-level).
            if adaptive {
                if let Some((arrive, lam)) = pending_update {
                    if clock >= arrive {
                        pending_update = None;
                        let moved = (lam - last_solved_lambda).abs()
                            > 0.1 * last_solved_lambda.max(1.0);
                        let remaining_tau = tau - clock;
                        if moved && remaining_tau > 0.0 {
                            last_solved_lambda = lam;
                            // Remaining schedule: rest of this level +
                            // later levels (only those already planned).
                            let mut sizes = vec![bytes_left];
                            let mut eps = vec![sched.eps[level]];
                            for j in level + 1..levels_sent {
                                sizes.push(sched.sizes[j]);
                                eps.push(sched.eps[j]);
                            }
                            // ε must strictly decrease; it does, since it
                            // is a suffix of the original schedule.
                            let sub = LevelSchedule::new(sizes, eps);
                            let p = NetParams { lambda: lam, ..*params };
                            if let Some(opt) = optimize_deadline_paper(&p, &sub, remaining_tau)
                            {
                                // Merge: keep plan for completed levels,
                                // replace the tail.
                                let mut new_plan = plan[..level].to_vec();
                                new_plan.extend(&opt.m);
                                // Pad dropped tail levels with the old
                                // plan if the re-solve sent fewer levels
                                // (they simply won't be reached before
                                // the deadline check below).
                                while new_plan.len() < plan.len() {
                                    new_plan.push(plan[new_plan.len()]);
                                }
                                if new_plan != plan {
                                    plan = new_plan;
                                    result.plan_changes.push((level, plan.clone()));
                                }
                            }
                        }
                    }
                }
            }

            let m_i = plan[level].min(n - 1);
            let k = (n - m_i).min(bytes_left.div_ceil(s).max(1) as usize);
            bytes_left = bytes_left.saturating_sub(k as u64 * s);

            // Transmit this FTG's fragments.
            let mut lost_in_group = 0usize;
            for _ in 0..k + m_i {
                let depart = clock;
                clock += step;
                result.fragments_sent += 1;
                if loss.is_lost(depart) {
                    result.fragments_lost += 1;
                    lost_in_group += 1;
                    window_losses += 1;
                } else {
                    last_arrival = last_arrival.max(depart + t);
                }
                let arrive = depart + t;
                if adaptive && arrive - window_start >= t_w {
                    let lambda_hat = window_losses as f64 / t_w;
                    result.lambda_updates.push((arrive, lambda_hat));
                    pending_update = Some((arrive + t, lambda_hat));
                    window_start = arrive;
                    window_losses = 0;
                }
            }
            if lost_in_group > m_i {
                result.level_ok[level] = false;
            }
        }
    }

    // END notification.
    result.total_time = last_arrival.max(clock + t);
    // Usable prefix: levels 1..i all fully recovered.
    let mut prefix = 0;
    for &ok in &result.level_ok {
        if ok {
            prefix += 1;
        } else {
            break;
        }
    }
    result.levels_recovered = prefix;
    result.achieved_eps = sched.eps_with_levels(prefix);
    Some(result)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::hmm::{HmmConfig, HmmLoss};
    use crate::sim::loss::{NoLoss, StaticLoss};

    const TTL: f64 = 1.0 / 19_144.0;

    fn params(lambda: f64) -> NetParams {
        NetParams::paper_default(lambda)
    }

    fn sched() -> LevelSchedule {
        LevelSchedule::paper_nyx_scaled(1000)
    }

    #[test]
    fn lossless_recovers_all_levels() {
        let p = params(0.0);
        let s = sched();
        let res =
            run_guaranteed_time(&mut NoLoss, &p, &s, 1.0, &DeadlinePolicy::Static(vec![0; 4]))
                .unwrap();
        assert_eq!(res.levels_recovered, 4);
        assert!((res.achieved_eps - 1e-7).abs() < 1e-12);
        assert!(res.level_ok.iter().all(|&b| b));
    }

    #[test]
    fn respects_deadline_with_static_plan() {
        // A plan solved for τ must finish within ~τ (wire-time accounting:
        // no retransmission ⇒ deterministic duration).
        let p = params(383.0);
        let s = sched();
        let tau = 0.45; // scaled-down analogue of the paper's ~400 s
        let opt = optimize_deadline_paper(&p, &s, tau);
        if let Some(opt) = opt {
            let mut loss = StaticLoss::with_ttl(383.0, 5, TTL);
            let res =
                run_guaranteed_time(&mut loss, &p, &s, tau, &DeadlinePolicy::Static(opt.m))
                    .unwrap();
            assert!(
                res.total_time <= tau * 1.05 + 2.0 * p.t,
                "time {} > τ {tau}",
                res.total_time
            );
        }
    }

    #[test]
    fn infeasible_deadline_returns_none_adaptive() {
        let p = params(19.0);
        let s = sched();
        let res = run_guaranteed_time(
            &mut NoLoss,
            &p,
            &s,
            1e-6,
            &DeadlinePolicy::Adaptive { t_w: 3.0, initial_lambda: 19.0 },
        );
        assert!(res.is_none());
    }

    #[test]
    fn unprotected_last_level_usually_dies_at_high_loss() {
        let p = params(957.0);
        let s = sched();
        let mut loss = StaticLoss::with_ttl(957.0, 9, TTL);
        let res = run_guaranteed_time(
            &mut loss,
            &p,
            &s,
            1.0,
            &DeadlinePolicy::Static(vec![12, 11, 11, 0]),
        )
        .unwrap();
        // The paper's Fig. 3 high-λ outcome: first three levels survive
        // (heavy parity), level 4 (m=0) is lost ⇒ ε_3.
        assert!(!res.level_ok[3], "level 4 with m=0 at 5% loss should fail");
        assert_eq!(res.levels_recovered, 3);
        assert!((res.achieved_eps - 6e-5).abs() < 1e-9);
    }

    #[test]
    fn more_parity_improves_achieved_error_distribution() {
        let p = params(957.0);
        let s = sched();
        let mut good = 0;
        let mut bad = 0;
        for seed in 0..20 {
            let mut l1 = StaticLoss::with_ttl(957.0, seed, TTL);
            let strong = run_guaranteed_time(
                &mut l1,
                &p,
                &s,
                2.0,
                &DeadlinePolicy::Static(vec![12, 11, 11, 0]),
            )
            .unwrap();
            let mut l2 = StaticLoss::with_ttl(957.0, seed, TTL);
            let weak = run_guaranteed_time(
                &mut l2,
                &p,
                &s,
                2.0,
                &DeadlinePolicy::Static(vec![1, 1, 1, 1]),
            )
            .unwrap();
            if strong.levels_recovered >= 3 {
                good += 1;
            }
            if weak.levels_recovered < 3 {
                bad += 1;
            }
        }
        assert!(good >= 18, "optimized plan recovered 3 levels only {good}/20");
        assert!(bad >= 18, "uniform m=1 plan survived too often: {}", 20 - bad);
    }

    #[test]
    fn adaptive_replans_under_hmm_loss() {
        let p = params(19.0);
        let s = LevelSchedule::paper_nyx_scaled(100);
        // Faster transitions so the scaled run sees several states.
        let cfg = HmmConfig { transition_rate: 2.0, ..HmmConfig::default() };
        let mut loss = HmmLoss::with_ttl(cfg, 13, TTL);
        let res = run_guaranteed_time(
            &mut loss,
            &p,
            &s,
            6.0,
            &DeadlinePolicy::Adaptive { t_w: 0.5, initial_lambda: 19.0 },
        )
        .unwrap();
        assert!(!res.lambda_updates.is_empty());
        assert!(
            res.plan_changes.len() >= 2,
            "plan should adapt: {:?}",
            res.plan_changes
        );
        assert!(res.total_time <= 6.0 + 0.1);
    }

    #[test]
    fn deterministic_for_seed() {
        let p = params(383.0);
        let s = sched();
        let run = |seed| {
            let mut loss = StaticLoss::with_ttl(383.0, seed, TTL);
            run_guaranteed_time(
                &mut loss,
                &p,
                &s,
                1.0,
                &DeadlinePolicy::Static(vec![8, 7, 7, 0]),
            )
            .unwrap()
        };
        let a = run(3);
        let b = run(3);
        assert_eq!(a.levels_recovered, b.levels_recovered);
        assert_eq!(a.fragments_lost, b.fragments_lost);
        assert!((a.total_time - b.total_time).abs() < 1e-12);
    }
}
