//! Packet-loss-rate estimators — simulator-facing surface.
//!
//! The estimator family itself lives in [`crate::coordinator::estimate`]
//! now that the transfer engines consume it at the pass barrier (PR 6);
//! this module re-exports it unchanged for existing `sim::` users and
//! keeps [`tracking_rmse`], which depends on [`crate::sim::loss`] ground
//! truth and therefore stays on the simulator side.

pub use crate::coordinator::estimate::{
    EwmaEstimator, LambdaEstimator, PassObservation, TwoStateEstimator, WindowEstimator,
};

/// Drive an estimator along an HMM loss trace at packet granularity and
/// return its root-mean-square tracking error against the true λ(t).
pub fn tracking_rmse(
    est: &mut dyn LambdaEstimator,
    loss: &mut dyn crate::sim::loss::LossProcess,
    rate: f64,
    horizon: f64,
) -> f64 {
    let step = 1.0 / rate;
    let mut t = 0.0;
    let mut se = 0.0;
    let mut samples = 0u64;
    while t < horizon {
        let lost = loss.is_lost(t);
        est.record_losses(t, lost as u64);
        if samples % 1024 == 0 {
            if let Some(e) = est.estimate() {
                let truth = loss.rate_at(t);
                se += (e - truth).powi(2);
            }
        }
        samples += 1;
        t += step;
    }
    (se / (samples / 1024).max(1) as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::hmm::HmmLoss;
    use crate::sim::loss::{LossProcess, StaticLoss};

    #[test]
    fn window_estimator_converges_on_static_loss() {
        let mut est = WindowEstimator::new(1.0);
        let mut loss = StaticLoss::with_ttl(383.0, 1, 1.0 / 19_144.0);
        let step = 1.0 / 19_144.0;
        let mut t = 0.0;
        while t < 30.0 {
            est.record_losses(t, loss.is_lost(t) as u64);
            t += step;
        }
        let e = est.estimate().expect("warmed up");
        assert!((e - 383.0).abs() / 383.0 < 0.15, "λ̂={e}");
    }

    #[test]
    fn ewma_smooths_more_than_window() {
        // Under *static* loss, EWMA's variance across reads is smaller.
        let run = |mk: &mut dyn LambdaEstimator| -> f64 {
            let mut loss = StaticLoss::with_ttl(383.0, 3, 1.0 / 19_144.0);
            let step = 1.0 / 19_144.0;
            let mut t = 0.0;
            let mut reads = Vec::new();
            while t < 60.0 {
                mk.record_losses(t, loss.is_lost(t) as u64);
                if let Some(e) = mk.estimate() {
                    reads.push(e);
                }
                t += step;
            }
            crate::util::stats::stddev(&reads)
        };
        let sd_window = run(&mut WindowEstimator::new(1.0));
        let sd_ewma = run(&mut EwmaEstimator::new(1.0, 0.25));
        assert!(
            sd_ewma < sd_window,
            "EWMA σ {sd_ewma} !< window σ {sd_window}"
        );
    }

    #[test]
    fn tracking_rmse_finite_on_hmm() {
        let mut est = WindowEstimator::new(3.0);
        let mut loss = HmmLoss::paper_default_with_ttl(5, 1.0 / 19_144.0);
        let rmse = tracking_rmse(&mut est, &mut loss, 19_144.0, 120.0);
        assert!(rmse.is_finite() && rmse > 0.0);
        // λ spans 19..957; a sane estimator tracks within the state gap.
        assert!(rmse < 500.0, "rmse={rmse}");
    }

    #[test]
    fn no_estimate_before_first_window() {
        let mut est = WindowEstimator::new(3.0);
        est.record_losses(0.5, 1);
        assert!(est.estimate().is_none());
    }
}
