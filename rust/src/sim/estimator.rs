//! Packet-loss-rate estimators.
//!
//! The paper's receiver estimates λ by counting losses in a window `T_W`
//! (§4). This module provides that estimator plus an EWMA variant, with a
//! common trait so the ablation bench can compare tracking error against
//! the HMM ground truth (the paper cites HMM-based prediction work [37,
//! 38, 41] as the natural extension).

/// Online λ estimator fed with per-window loss counts or raw events.
pub trait LambdaEstimator {
    /// Record that `lost` fragments were detected missing at `time`.
    fn record_losses(&mut self, time: f64, lost: u64);
    /// Current estimate (losses/second), if warmed up.
    fn estimate(&self) -> Option<f64>;
    fn name(&self) -> &'static str;
}

/// The paper's estimator: losses per fixed window `T_W`.
#[derive(Debug, Clone)]
pub struct WindowEstimator {
    t_w: f64,
    window_start: f64,
    window_losses: u64,
    last: Option<f64>,
}

impl WindowEstimator {
    pub fn new(t_w: f64) -> Self {
        assert!(t_w > 0.0);
        WindowEstimator { t_w, window_start: 0.0, window_losses: 0, last: None }
    }
}

impl LambdaEstimator for WindowEstimator {
    fn record_losses(&mut self, time: f64, lost: u64) {
        if time - self.window_start >= self.t_w {
            let elapsed = time - self.window_start;
            self.last = Some(self.window_losses as f64 / elapsed);
            self.window_start = time;
            self.window_losses = 0;
        }
        self.window_losses += lost;
    }
    fn estimate(&self) -> Option<f64> {
        self.last
    }
    fn name(&self) -> &'static str {
        "window"
    }
}

/// Exponentially-weighted moving average over sub-windows: smoother than
/// the raw window estimate, faster to react than enlarging `T_W`.
#[derive(Debug, Clone)]
pub struct EwmaEstimator {
    sub_window: f64,
    alpha: f64,
    window_start: f64,
    window_losses: u64,
    value: Option<f64>,
}

impl EwmaEstimator {
    pub fn new(sub_window: f64, alpha: f64) -> Self {
        assert!(sub_window > 0.0 && (0.0..=1.0).contains(&alpha));
        EwmaEstimator { sub_window, alpha, window_start: 0.0, window_losses: 0, value: None }
    }
}

impl LambdaEstimator for EwmaEstimator {
    fn record_losses(&mut self, time: f64, lost: u64) {
        if time - self.window_start >= self.sub_window {
            let elapsed = time - self.window_start;
            let sample = self.window_losses as f64 / elapsed;
            self.value = Some(match self.value {
                Some(v) => self.alpha * sample + (1.0 - self.alpha) * v,
                None => sample,
            });
            self.window_start = time;
            self.window_losses = 0;
        }
        self.window_losses += lost;
    }
    fn estimate(&self) -> Option<f64> {
        self.value
    }
    fn name(&self) -> &'static str {
        "ewma"
    }
}

/// Drive an estimator along an HMM loss trace at packet granularity and
/// return its root-mean-square tracking error against the true λ(t).
pub fn tracking_rmse(
    est: &mut dyn LambdaEstimator,
    loss: &mut dyn crate::sim::loss::LossProcess,
    rate: f64,
    horizon: f64,
) -> f64 {
    let step = 1.0 / rate;
    let mut t = 0.0;
    let mut se = 0.0;
    let mut samples = 0u64;
    while t < horizon {
        let lost = loss.is_lost(t);
        est.record_losses(t, lost as u64);
        if samples % 1024 == 0 {
            if let Some(e) = est.estimate() {
                let truth = loss.rate_at(t);
                se += (e - truth).powi(2);
            }
        }
        samples += 1;
        t += step;
    }
    (se / (samples / 1024).max(1) as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::hmm::HmmLoss;
    use crate::sim::loss::{LossProcess, StaticLoss};

    #[test]
    fn window_estimator_converges_on_static_loss() {
        let mut est = WindowEstimator::new(1.0);
        let mut loss = StaticLoss::with_ttl(383.0, 1, 1.0 / 19_144.0);
        let step = 1.0 / 19_144.0;
        let mut t = 0.0;
        while t < 30.0 {
            est.record_losses(t, loss.is_lost(t) as u64);
            t += step;
        }
        let e = est.estimate().expect("warmed up");
        assert!((e - 383.0).abs() / 383.0 < 0.15, "λ̂={e}");
    }

    #[test]
    fn ewma_smooths_more_than_window() {
        // Under *static* loss, EWMA's variance across reads is smaller.
        let run = |mk: &mut dyn LambdaEstimator| -> f64 {
            let mut loss = StaticLoss::with_ttl(383.0, 3, 1.0 / 19_144.0);
            let step = 1.0 / 19_144.0;
            let mut t = 0.0;
            let mut reads = Vec::new();
            while t < 60.0 {
                mk.record_losses(t, loss.is_lost(t) as u64);
                if let Some(e) = mk.estimate() {
                    reads.push(e);
                }
                t += step;
            }
            crate::util::stats::stddev(&reads)
        };
        let sd_window = run(&mut WindowEstimator::new(1.0));
        let sd_ewma = run(&mut EwmaEstimator::new(1.0, 0.25));
        assert!(
            sd_ewma < sd_window,
            "EWMA σ {sd_ewma} !< window σ {sd_window}"
        );
    }

    #[test]
    fn tracking_rmse_finite_on_hmm() {
        let mut est = WindowEstimator::new(3.0);
        let mut loss = HmmLoss::paper_default_with_ttl(5, 1.0 / 19_144.0);
        let rmse = tracking_rmse(&mut est, &mut loss, 19_144.0, 120.0);
        assert!(rmse.is_finite() && rmse > 0.0);
        // λ spans 19..957; a sane estimator tracks within the state gap.
        assert!(rmse < 500.0, "rmse={rmse}");
    }

    #[test]
    fn no_estimate_before_first_window() {
        let mut est = WindowEstimator::new(3.0);
        est.record_losses(0.5, 1);
        assert!(est.estimate().is_none());
    }
}
