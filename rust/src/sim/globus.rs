//! Globus-like baseline (Fig. 6): a managed transfer service layered on
//! parallel TCP streams.
//!
//! GridFTP-style services stripe a dataset over several TCP connections
//! and add control-plane overhead (endpoint activation, transfer-task
//! scheduling) plus a post-transfer integrity pass (checksum of the whole
//! dataset). We model:
//!   * `streams` independent Reno flows, each carrying `1/streams` of the
//!     data and pacing at `r/streams` (fair share of the bottleneck);
//!   * fixed startup latency;
//!   * a checksum pass at `checksum_rate` bytes/s after the slowest
//!     stream finishes.
//! Total time = startup + max(stream times) + checksum.

use super::loss::{BernoulliLoss, LossProcess};
use super::tcp::{run_tcp, TcpResult};
use crate::model::params::NetParams;

/// Globus-like service model parameters.
#[derive(Debug, Clone)]
pub struct GlobusConfig {
    /// Parallel TCP streams (GridFTP default parallelism is 4).
    pub streams: usize,
    /// Control-plane startup overhead, seconds.
    pub startup: f64,
    /// Post-transfer checksum throughput, bytes/s (0 = disabled).
    pub checksum_rate: f64,
}

impl Default for GlobusConfig {
    fn default() -> Self {
        GlobusConfig {
            streams: 4,
            startup: 15.0,
            checksum_rate: 500.0 * 1024.0 * 1024.0,
        }
    }
}

/// Outcome of a simulated Globus-style transfer.
#[derive(Debug, Clone)]
pub struct GlobusResult {
    pub total_time: f64,
    pub per_stream: Vec<TcpResult>,
}

/// Simulate a Globus-like transfer of `total_bytes` with per-packet loss
/// fraction `loss_fraction` (each stream draws independently).
pub fn run_globus(
    cfg: &GlobusConfig,
    params: &NetParams,
    total_bytes: u64,
    loss_fraction: f64,
    seed: u64,
) -> GlobusResult {
    assert!(cfg.streams >= 1);
    let share = NetParams { r: params.r / cfg.streams as f64, ..*params };
    let per_stream_bytes = total_bytes.div_ceil(cfg.streams as u64);
    let mut per_stream = Vec::with_capacity(cfg.streams);
    let mut slowest = 0.0f64;
    for i in 0..cfg.streams {
        let mut loss = BernoulliLoss::new(loss_fraction, seed ^ (0x610B05 + i as u64));
        let res = run_tcp(&mut loss, &share, per_stream_bytes);
        slowest = slowest.max(res.total_time);
        per_stream.push(res);
    }
    let checksum = if cfg.checksum_rate > 0.0 {
        total_bytes as f64 / cfg.checksum_rate
    } else {
        0.0
    };
    GlobusResult { total_time: cfg.startup + slowest + checksum, per_stream }
}

/// Variant driven by a rate-based loss process sampled at transfer start
/// (for scenarios where λ fluctuates between runs but not within one).
pub fn run_globus_with_loss(
    cfg: &GlobusConfig,
    params: &NetParams,
    total_bytes: u64,
    loss: &mut dyn LossProcess,
    seed: u64,
) -> GlobusResult {
    let fraction = (loss.rate_at(0.0) / params.r).clamp(0.0, 1.0);
    run_globus(cfg, params, total_bytes, fraction, seed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallel_streams_beat_single_tcp_under_loss() {
        let p = NetParams::paper_default(0.0);
        let bytes = 50u64 * 1024 * 1024;
        let single = {
            let mut l = BernoulliLoss::new(0.02, 1);
            run_tcp(&mut l, &p, bytes).total_time
        };
        let cfg = GlobusConfig { startup: 0.0, checksum_rate: 0.0, streams: 4 };
        let multi = run_globus(&cfg, &p, bytes, 0.02, 1).total_time;
        assert!(
            multi < single,
            "4 striped streams {multi} !< single {single}"
        );
    }

    #[test]
    fn overheads_added() {
        let p = NetParams::paper_default(0.0);
        let bytes = 10u64 * 1024 * 1024;
        let bare = GlobusConfig { startup: 0.0, checksum_rate: 0.0, streams: 2 };
        let loaded = GlobusConfig {
            startup: 20.0,
            checksum_rate: 1024.0 * 1024.0,
            streams: 2,
        };
        let t_bare = run_globus(&bare, &p, bytes, 0.0, 2).total_time;
        let t_loaded = run_globus(&loaded, &p, bytes, 0.0, 2).total_time;
        assert!((t_loaded - t_bare - 20.0 - 10.0).abs() < 0.5);
    }

    #[test]
    fn all_streams_complete() {
        let p = NetParams::paper_default(0.0);
        let res = run_globus(&GlobusConfig::default(), &p, 8 * 1024 * 1024, 0.01, 3);
        assert_eq!(res.per_stream.len(), 4);
        assert!(res.per_stream.iter().all(|s| s.total_time > 0.0));
    }
}
