//! Discrete-event network simulation: engine, loss processes, and the
//! protocol models evaluated in the paper (§5.2).

pub mod deadline;
pub mod estimator;
pub mod engine;
pub mod globus;
pub mod hmm;
pub mod loss;
pub mod tcp;
pub mod udp_ec;

pub use engine::{run, Scheduler, SimTime, World};
pub use estimator::{EwmaEstimator, LambdaEstimator, WindowEstimator};
pub use hmm::{HmmConfig, HmmLoss, HmmState};
pub use deadline::{run_guaranteed_time, DeadlinePolicy, DeadlineResult};
pub use globus::{run_globus, GlobusConfig, GlobusResult};
pub use loss::{BernoulliLoss, FractionOfRate, LossProcess, NoLoss, StaticLoss};
pub use tcp::{run_tcp, TcpResult};
pub use udp_ec::{run_guaranteed_error, ParityPolicy, TransferResult};
