//! Discrete-event simulation engine (SimPy substitute, DESIGN.md §3).
//!
//! A minimal, fast, deterministic event-queue kernel: the protocol models
//! (`tcp`, `udp_ec`, `adaptive`, ...) define an event enum and a [`World`]
//! that mutates its state on each event, scheduling follow-up events
//! through the [`Scheduler`]. Ties are broken by insertion sequence so
//! runs are fully reproducible.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Simulated clock, in seconds.
pub type SimTime = f64;

struct Entry<E> {
    time: SimTime,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first. NaN times
        // are rejected at scheduling, so total order is safe here.
        other
            .time
            .partial_cmp(&self.time)
            .unwrap()
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Pending-event queue handed to [`World::handle`].
pub struct Scheduler<E> {
    heap: BinaryHeap<Entry<E>>,
    now: SimTime,
    seq: u64,
    processed: u64,
}

impl<E> Scheduler<E> {
    pub fn new() -> Self {
        Scheduler { heap: BinaryHeap::new(), now: 0.0, seq: 0, processed: 0 }
    }

    /// Current simulated time.
    #[inline]
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Number of events processed so far.
    #[inline]
    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Schedule `event` to fire `delay` seconds from now.
    #[inline]
    pub fn schedule(&mut self, delay: SimTime, event: E) {
        self.schedule_at(self.now + delay, event)
    }

    /// Schedule `event` at an absolute time (must not be in the past).
    #[inline]
    pub fn schedule_at(&mut self, time: SimTime, event: E) {
        assert!(time.is_finite(), "non-finite event time");
        assert!(
            time >= self.now - 1e-12,
            "scheduling into the past: {time} < {}",
            self.now
        );
        self.heap.push(Entry { time: time.max(self.now), seq: self.seq, event });
        self.seq += 1;
    }

    fn pop(&mut self) -> Option<(SimTime, E)> {
        self.heap.pop().map(|e| (e.time, e.event))
    }

    /// True when no events are pending.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

impl<E> Default for Scheduler<E> {
    fn default() -> Self {
        Self::new()
    }
}

/// A simulation model: state + event handler.
pub trait World {
    type Event;
    /// Handle one event at simulated time `now`. Schedule follow-ups via
    /// `sched`. Return `false` to stop the simulation early.
    fn handle(&mut self, now: SimTime, event: Self::Event, sched: &mut Scheduler<Self::Event>) -> bool;
}

/// Drive `world` until the queue drains, `world.handle` returns false, or
/// `max_events` safety limit trips. Returns the final simulated time.
pub fn run<W: World>(world: &mut W, sched: &mut Scheduler<W::Event>, max_events: u64) -> SimTime {
    while let Some((time, event)) = sched.pop() {
        sched.now = time;
        sched.processed += 1;
        if !world.handle(time, event, sched) {
            break;
        }
        if sched.processed >= max_events {
            panic!("simulation exceeded {max_events} events — runaway model?");
        }
    }
    sched.now
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Debug, PartialEq)]
    enum Ev {
        Ping(u32),
        Stop,
    }

    struct Recorder {
        seen: Vec<(SimTime, u32)>,
    }

    impl World for Recorder {
        type Event = Ev;
        fn handle(&mut self, now: SimTime, ev: Ev, sched: &mut Scheduler<Ev>) -> bool {
            match ev {
                Ev::Ping(i) => {
                    self.seen.push((now, i));
                    if i < 3 {
                        sched.schedule(1.5, Ev::Ping(i + 1));
                    }
                    true
                }
                Ev::Stop => false,
            }
        }
    }

    #[test]
    fn events_fire_in_time_order() {
        let mut w = Recorder { seen: vec![] };
        let mut s = Scheduler::new();
        s.schedule(2.0, Ev::Ping(10));
        s.schedule(1.0, Ev::Ping(20));
        s.schedule(3.0, Ev::Ping(30));
        run(&mut w, &mut s, 1000);
        let ids: Vec<u32> = w.seen.iter().map(|&(_, i)| i).collect();
        assert_eq!(ids, vec![20, 10, 30]);
    }

    #[test]
    fn chained_scheduling_advances_clock() {
        let mut w = Recorder { seen: vec![] };
        let mut s = Scheduler::new();
        s.schedule(0.0, Ev::Ping(0));
        let end = run(&mut w, &mut s, 1000);
        assert_eq!(w.seen.len(), 4);
        assert!((end - 4.5).abs() < 1e-12, "end={end}");
        assert!((w.seen[3].0 - 4.5).abs() < 1e-12);
    }

    #[test]
    fn stop_event_halts_early() {
        let mut w = Recorder { seen: vec![] };
        let mut s = Scheduler::new();
        s.schedule(1.0, Ev::Stop);
        s.schedule(2.0, Ev::Ping(99));
        run(&mut w, &mut s, 1000);
        assert!(w.seen.is_empty());
    }

    #[test]
    fn ties_broken_by_insertion_order() {
        let mut w = Recorder { seen: vec![] };
        let mut s = Scheduler::new();
        for i in 10..20 {
            s.schedule(1.0, Ev::Ping(i));
        }
        run(&mut w, &mut s, 1000);
        let ids: Vec<u32> = w.seen.iter().map(|&(_, i)| i).collect();
        assert_eq!(ids, (10..20).collect::<Vec<_>>());
    }

    #[test]
    #[should_panic(expected = "scheduling into the past")]
    fn past_scheduling_rejected() {
        struct Bad;
        impl World for Bad {
            type Event = ();
            fn handle(&mut self, _: SimTime, _: (), s: &mut Scheduler<()>) -> bool {
                s.schedule_at(s.now() - 1.0, ());
                true
            }
        }
        let mut s = Scheduler::new();
        s.schedule(5.0, ());
        run(&mut Bad, &mut s, 10);
    }

    #[test]
    #[should_panic(expected = "runaway")]
    fn runaway_guard_trips() {
        struct Loop;
        impl World for Loop {
            type Event = ();
            fn handle(&mut self, _: SimTime, _: (), s: &mut Scheduler<()>) -> bool {
                s.schedule(0.0, ());
                true
            }
        }
        let mut s = Scheduler::new();
        s.schedule(0.0, ());
        run(&mut Loop, &mut s, 100);
    }
}
