//! UDP + erasure-coding transfer with passive retransmission —
//! the guaranteed-error-bound protocol (paper Alg. 1), simulated.
//!
//! Covers both the static-parity variant (Fig. 2) and the adaptive
//! variant that re-solves Eq. 8 on receiver λ-updates (Fig. 4). The
//! packet stream is rate-paced (one fragment every `1/r` seconds), so it
//! is simulated arithmetically packet-by-packet; only the control plane
//! (λ windows, end-of-round exchanges) needs timeline bookkeeping.

use super::loss::LossProcess;
use crate::model::params::{LevelSchedule, NetParams};
use crate::model::time_model::optimize_parity;

/// Parity policy for the guaranteed-error-bound transfer.
#[derive(Debug, Clone)]
pub enum ParityPolicy {
    /// Fixed m for every FTG (the paper's "static fault tolerance").
    Static(usize),
    /// Alg. 1: start from Eq. 8's optimum for the initial λ estimate and
    /// re-solve whenever the receiver reports a new λ (window `t_w`).
    Adaptive {
        /// Receiver measurement window `T_W`, seconds (paper: 3 s).
        t_w: f64,
        /// Initial λ estimate fed to the first Eq. 8 solve.
        initial_lambda: f64,
    },
}

/// Outcome of one simulated guaranteed-error-bound transfer.
#[derive(Debug, Clone)]
pub struct TransferResult {
    /// Time until the receiver has recovered every required FTG, seconds.
    pub total_time: f64,
    /// Retransmission rounds needed (0 = everything recovered first pass).
    pub rounds: usize,
    /// Total fragments put on the wire (including parity and retries).
    pub fragments_sent: u64,
    /// Fragments dropped by the loss process.
    pub fragments_lost: u64,
    /// FTGs that needed retransmission, summed over rounds.
    pub ftgs_retransmitted: u64,
    /// λ estimates reported by the receiver over time (time, λ̂).
    pub lambda_updates: Vec<(f64, f64)>,
    /// m values used over the FTG stream (ftg_index, m) — records policy
    /// adaptation.
    pub m_changes: Vec<(u64, usize)>,
}

/// One FTG's bookkeeping during a pass.
#[derive(Debug, Clone, Copy)]
struct FtgSpec {
    k: usize,
    m: usize,
}

/// Simulate Alg. 1 (guaranteed error bound): transfer the first `levels`
/// levels of `sched`, recover losses with parity, passively retransmit
/// unrecoverable FTGs until everything needed has arrived.
pub fn run_guaranteed_error(
    loss: &mut dyn LossProcess,
    params: &NetParams,
    sched: &LevelSchedule,
    levels: usize,
    policy: &ParityPolicy,
) -> TransferResult {
    assert!(levels >= 1 && levels <= sched.num_levels());
    let n = params.n;
    let s = params.s as u64;
    let r = params.r;
    let t = params.t;
    let total_bytes: u64 = sched.total_bytes(levels);
    let total_data_fragments = total_bytes.div_ceil(s);

    let mut result = TransferResult {
        total_time: 0.0,
        rounds: 0,
        fragments_sent: 0,
        fragments_lost: 0,
        ftgs_retransmitted: 0,
        lambda_updates: Vec::new(),
        m_changes: Vec::new(),
    };

    // Current m, per policy.
    let mut current_m = match policy {
        ParityPolicy::Static(m) => {
            assert!(*m <= n / 2, "m must be ≤ n/2");
            *m
        }
        ParityPolicy::Adaptive { initial_lambda, .. } => {
            let p = NetParams { lambda: *initial_lambda, ..*params };
            optimize_parity(&p, total_bytes).m
        }
    };
    result.m_changes.push((0, current_m));

    // Receiver-side λ measurement window state.
    let (t_w, adaptive) = match policy {
        ParityPolicy::Adaptive { t_w, .. } => (*t_w, true),
        ParityPolicy::Static(_) => (f64::INFINITY, false),
    };
    let mut window_start = 0.0f64;
    let mut window_losses = 0u64;
    // λ update in flight toward the sender: (arrival_time, lambda).
    let mut pending_update: Option<(f64, f64)> = None;
    let mut last_solved_lambda = match policy {
        ParityPolicy::Adaptive { initial_lambda, .. } => *initial_lambda,
        _ => 0.0,
    };

    // Clock: next fragment departs at `clock`; fragments depart every 1/r.
    let mut clock = 0.0f64;
    let step = 1.0 / r;

    // Work queue for the current pass: FTGs to (re)send. First pass is
    // generated lazily (data fragments consumed in order); retransmission
    // passes replay recorded specs.
    let mut data_remaining = total_data_fragments;
    let mut first_pass_specs: Vec<FtgSpec> = Vec::new();
    let mut lost_ftgs: Vec<FtgSpec> = Vec::new(); // unrecoverable this pass
    let mut last_arrival = 0.0f64;
    let mut ftg_index = 0u64;

    // === First pass + retransmission rounds ===
    // Passes: 0 = initial (generate FTGs), 1.. = retransmit lost list.
    let mut retransmit_queue: Vec<FtgSpec> = Vec::new();
    loop {
        let first_pass = result.rounds == 0;
        let mut queue_pos = 0usize;
        loop {
            // Produce the next FTG spec for this pass.
            let spec = if first_pass {
                if data_remaining == 0 {
                    break;
                }
                // Apply any λ update that has reached the sender. Alg. 1
                // recomputes m for data not yet encoded.
                if let Some((arrive, lam)) = pending_update {
                    if clock >= arrive {
                        pending_update = None;
                        // Throttle: re-solving Eq. 8 for a λ̂ within 10% of
                        // the last solved value cannot change m enough to
                        // matter and burns solver time on the hot path.
                        let moved = (lam - last_solved_lambda).abs()
                            > 0.1 * last_solved_lambda.max(1.0);
                        if moved {
                            last_solved_lambda = lam;
                            let p = NetParams { lambda: lam, ..*params };
                            let new_m = optimize_parity(&p, data_remaining * s).m;
                            if new_m != current_m {
                                current_m = new_m;
                                result.m_changes.push((ftg_index, new_m));
                            }
                        }
                    }
                }
                let k = (n - current_m).min(data_remaining.max(1) as usize);
                data_remaining = data_remaining.saturating_sub(k as u64);
                let spec = FtgSpec { k, m: current_m };
                first_pass_specs.push(spec);
                spec
            } else {
                if queue_pos >= retransmit_queue.len() {
                    break;
                }
                queue_pos += 1;
                retransmit_queue[queue_pos - 1]
            };

            // Transmit the FTG's k+m fragments.
            let mut lost_in_group = 0usize;
            for _ in 0..spec.k + spec.m {
                let depart = clock;
                clock += step;
                result.fragments_sent += 1;
                let lost = loss.is_lost(depart);
                let arrive = depart + t;
                if lost {
                    result.fragments_lost += 1;
                    lost_in_group += 1;
                    window_losses += 1;
                } else {
                    last_arrival = last_arrival.max(arrive);
                }
                // Receiver window bookkeeping (loss detection happens at
                // expected-arrival time via sequence gaps).
                if adaptive && arrive - window_start >= t_w {
                    let lambda_hat = window_losses as f64 / t_w;
                    result.lambda_updates.push((arrive, lambda_hat));
                    // Control message back to the sender takes t.
                    pending_update = Some((arrive + t, lambda_hat));
                    window_start = arrive;
                    window_losses = 0;
                }
            }
            if lost_in_group > spec.m {
                lost_ftgs.push(spec);
            }
            ftg_index += 1;
        }

        // End-of-pass control exchange: END notification reaches the
        // receiver t after the last departure; the lost-FTG list reaches
        // the sender t later.
        let end_at_receiver = clock + t;
        if lost_ftgs.is_empty() {
            // Completion: all FTGs recovered. Total time is when the last
            // fragment arrived (paper's Eq. 2 accounting), bounded below
            // by the END exchange.
            result.total_time = last_arrival.max(end_at_receiver);
            return result;
        }
        result.rounds += 1;
        result.ftgs_retransmitted += lost_ftgs.len() as u64;
        retransmit_queue = std::mem::take(&mut lost_ftgs);
        // Sender resumes after the list arrives.
        clock = end_at_receiver + t;
        assert!(
            result.rounds < 10_000,
            "retransmission did not converge (λ too high for parity?)"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::loss::{NoLoss, StaticLoss};

    const TTL: f64 = 1.0 / 19_144.0;

    fn params(lambda: f64) -> NetParams {
        NetParams::paper_default(lambda)
    }

    /// Scaled schedule so tests run in milliseconds.
    fn sched() -> LevelSchedule {
        LevelSchedule::paper_nyx_scaled(1000)
    }

    #[test]
    fn lossless_transfer_matches_wire_time() {
        let p = params(0.0);
        let s = sched();
        let res = run_guaranteed_error(&mut NoLoss, &p, &s, 4, &ParityPolicy::Static(0));
        assert_eq!(res.rounds, 0);
        assert_eq!(res.fragments_lost, 0);
        // Expected: N groups of 32 fragments at r f/s plus latency t.
        let frags = s.total_bytes(4).div_ceil(4096);
        let expect = frags as f64 / p.r + p.t;
        assert!(
            (res.total_time - expect).abs() / expect < 0.01,
            "time={} expect={expect}",
            res.total_time
        );
    }

    #[test]
    fn parity_overhead_slows_lossless_transfer() {
        let p = params(0.0);
        let s = sched();
        let t0 = run_guaranteed_error(&mut NoLoss, &p, &s, 4, &ParityPolicy::Static(0)).total_time;
        let t8 = run_guaranteed_error(&mut NoLoss, &p, &s, 4, &ParityPolicy::Static(8)).total_time;
        let t16 =
            run_guaranteed_error(&mut NoLoss, &p, &s, 4, &ParityPolicy::Static(16)).total_time;
        assert!(t0 < t8 && t8 < t16);
        assert!((t16 / t0 - 2.0).abs() < 0.05, "m=16 should double time");
    }

    #[test]
    fn losses_trigger_retransmission_rounds_without_parity() {
        let p = params(383.0);
        let s = sched();
        let mut loss = StaticLoss::with_ttl(383.0, 42, TTL);
        let res = run_guaranteed_error(&mut loss, &p, &s, 4, &ParityPolicy::Static(0));
        assert!(res.rounds >= 1, "2% loss with m=0 must retransmit");
        assert!(res.fragments_lost > 0);
        assert!(res.ftgs_retransmitted > 0);
    }

    #[test]
    fn parity_reduces_retransmissions_at_medium_loss() {
        let p = params(383.0);
        let s = sched();
        let mut l0 = StaticLoss::with_ttl(383.0, 7, TTL);
        let r0 = run_guaranteed_error(&mut l0, &p, &s, 4, &ParityPolicy::Static(0));
        let mut l4 = StaticLoss::with_ttl(383.0, 7, TTL);
        let r4 = run_guaranteed_error(&mut l4, &p, &s, 4, &ParityPolicy::Static(4));
        assert!(
            r4.ftgs_retransmitted < r0.ftgs_retransmitted,
            "m=4 retrans {} !< m=0 retrans {}",
            r4.ftgs_retransmitted,
            r0.ftgs_retransmitted
        );
    }

    #[test]
    fn sim_time_matches_model_expectation() {
        // The paper's Fig. 2 observation: theory (Eq. 2) aligns with sim.
        use crate::model::prob::p_unrecoverable;
        use crate::model::time_model::{expected_total_time, num_ftgs};
        let p = params(383.0);
        let s = sched();
        let bytes = s.total_bytes(4);
        for m in [2usize, 4, 8] {
            let p_loss = p_unrecoverable(&p, m);
            let model_t = expected_total_time(&p, num_ftgs(bytes, &p, m), p_loss);
            let mut times = Vec::new();
            for seed in 0..5 {
                let mut loss = StaticLoss::with_ttl(383.0, seed, TTL);
                times.push(
                    run_guaranteed_error(&mut loss, &p, &s, 4, &ParityPolicy::Static(m))
                        .total_time,
                );
            }
            let sim_t = crate::util::stats::mean(&times);
            assert!(
                (sim_t - model_t).abs() / model_t < 0.05,
                "m={m}: sim {sim_t:.3} vs model {model_t:.3}"
            );
        }
    }

    #[test]
    fn adaptive_reports_lambda_near_truth() {
        let p = params(383.0);
        let s = sched();
        let mut loss = StaticLoss::with_ttl(383.0, 11, TTL);
        let res = run_guaranteed_error(
            &mut loss,
            &p,
            &s,
            4,
            &ParityPolicy::Adaptive { t_w: 0.05, initial_lambda: 383.0 },
        );
        assert!(!res.lambda_updates.is_empty());
        let est: Vec<f64> = res.lambda_updates.iter().map(|&(_, l)| l).collect();
        let mean = crate::util::stats::mean(&est);
        assert!(
            (mean - 383.0).abs() / 383.0 < 0.25,
            "λ̂ mean {mean} far from 383"
        );
    }

    #[test]
    fn adaptive_switches_m_when_lambda_jumps() {
        // Loss process that jumps from low to high mid-transfer.
        struct Jump {
            inner_low: StaticLoss,
            inner_high: StaticLoss,
            switch_at: f64,
        }
        impl LossProcess for Jump {
            fn is_lost(&mut self, time: f64) -> bool {
                // Advance both processes to keep their clocks monotone.
                let lo = self.inner_low.is_lost(time);
                let hi = self.inner_high.is_lost(time);
                if time < self.switch_at {
                    lo
                } else {
                    hi
                }
            }
            fn rate_at(&mut self, time: f64) -> f64 {
                if time < self.switch_at {
                    19.0
                } else {
                    957.0
                }
            }
        }
        let p = params(19.0);
        let s = LevelSchedule::paper_nyx_scaled(100); // longer run
        let mut loss = Jump {
            inner_low: StaticLoss::with_ttl(19.0, 3, TTL),
            inner_high: StaticLoss::with_ttl(957.0, 4, TTL),
            switch_at: 1.5,
        };
        let res = run_guaranteed_error(
            &mut loss,
            &p,
            &s,
            4,
            &ParityPolicy::Adaptive { t_w: 0.5, initial_lambda: 19.0 },
        );
        assert!(
            res.m_changes.len() >= 2,
            "m should adapt after λ jump: {:?}",
            res.m_changes
        );
        let final_m = res.m_changes.last().unwrap().1;
        let initial_m = res.m_changes[0].1;
        assert!(
            final_m > initial_m,
            "m should grow with λ: {:?}",
            res.m_changes
        );
    }

    #[test]
    fn fewer_levels_transfer_faster() {
        let p = params(0.0);
        let s = sched();
        let t1 = run_guaranteed_error(&mut NoLoss, &p, &s, 1, &ParityPolicy::Static(0)).total_time;
        let t4 = run_guaranteed_error(&mut NoLoss, &p, &s, 4, &ParityPolicy::Static(0)).total_time;
        assert!(t1 < t4 / 10.0, "level 1 is ~2.5% of the data");
    }
}
