//! Packet-loss processes.
//!
//! Mirrors the paper's simulator (§5.2.1): "The packet loss process
//! simulates losses by generating random time intervals between losses.
//! When a loss event occurs, the packet is marked as lost if the loss
//! event queue is not empty. Afterward, the loss event queue is cleared."
//!
//! Concretely: loss events arrive as a (possibly non-homogeneous) Poisson
//! process with rate λ(t) (losses/second, §5.2.2). The first packet sent
//! at-or-after a pending loss event is dropped, and all loss events
//! pending at that moment are consumed — i.e. the realized drop rate is
//! min(λ, packet rate).

use crate::util::{dist, Pcg64};

/// A time-varying loss-event source consulted once per transmitted packet.
pub trait LossProcess {
    /// Should the packet sent at `time` be dropped?
    ///
    /// `time` must be non-decreasing across calls.
    fn is_lost(&mut self, time: f64) -> bool;

    /// Instantaneous configured loss rate λ(time) in losses/second —
    /// used by oracle baselines and for logging, not by the protocols
    /// (which must *estimate* λ from observations).
    fn rate_at(&mut self, time: f64) -> f64;
}

/// No losses at all (sanity baseline).
pub struct NoLoss;

impl LossProcess for NoLoss {
    fn is_lost(&mut self, _time: f64) -> bool {
        false
    }
    fn rate_at(&mut self, _time: f64) -> f64 {
        0.0
    }
}

/// Homogeneous Poisson loss events at fixed rate λ.
///
/// A packet sent at time `T` is lost when a loss event is *pending*:
/// occurred at most `ttl` seconds before `T` and not yet consumed by an
/// earlier packet. All pending events are cleared on a loss (paper
/// §5.2.1). The TTL bounds how long a loss event (a burst of congestion)
/// can linger: with the paper-literal unbounded queue, the first packet
/// sent after *any* idle gap ≳ 1/λ would deterministically die, making
/// single-FTG retransmission tails unrecoverable at high λ. During
/// continuous rate-`r` streaming any `ttl ≥ 1/r` is behaviour-identical
/// to the unbounded queue.
pub struct StaticLoss {
    lambda: f64,
    rng: Pcg64,
    /// Time of the next not-yet-consumed loss event; +inf when λ = 0.
    next_loss: f64,
    last_query: f64,
    ttl: f64,
}

impl StaticLoss {
    /// Paper-literal semantics: loss events never expire.
    pub fn new(lambda: f64, seed: u64) -> Self {
        Self::with_ttl(lambda, seed, f64::INFINITY)
    }

    /// Loss events expire `ttl` seconds after they occur. Protocol
    /// simulations use `ttl = 1/r` (one packet service time).
    pub fn with_ttl(lambda: f64, seed: u64, ttl: f64) -> Self {
        assert!(lambda >= 0.0);
        assert!(ttl > 0.0);
        let mut rng = Pcg64::seeded(seed);
        let next_loss = if lambda > 0.0 {
            dist::exponential(&mut rng, lambda)
        } else {
            f64::INFINITY
        };
        StaticLoss { lambda, rng, next_loss, last_query: 0.0, ttl }
    }
}

impl LossProcess for StaticLoss {
    fn is_lost(&mut self, time: f64) -> bool {
        debug_assert!(time >= self.last_query - 1e-9, "time went backwards");
        self.last_query = time;
        // Expire events that are too stale to affect this packet.
        let horizon = time - self.ttl;
        while self.next_loss < horizon {
            self.next_loss += dist::exponential(&mut self.rng, self.lambda);
        }
        if time + 1e-15 < self.next_loss {
            return false;
        }
        // Consume every loss event pending at `time` (the paper clears the
        // loss-event queue after marking one packet lost).
        while self.next_loss <= time + 1e-15 {
            self.next_loss += dist::exponential(&mut self.rng, self.lambda);
        }
        true
    }

    fn rate_at(&mut self, _time: f64) -> f64 {
        self.lambda
    }
}

/// Per-packet Bernoulli loss with fixed probability.
///
/// Used for the TCP/Globus baselines, where the meaningful quantity is a
/// loss *fraction* (0.1% / 2% / 5%, §5.2.2): a rate-based process would
/// make the fraction explode as TCP backs off, compounding unfairly.
pub struct BernoulliLoss {
    p: f64,
    rng: Pcg64,
}

impl BernoulliLoss {
    pub fn new(p: f64, seed: u64) -> Self {
        assert!((0.0..=1.0).contains(&p));
        BernoulliLoss { p, rng: Pcg64::seeded(seed) }
    }
}

impl LossProcess for BernoulliLoss {
    fn is_lost(&mut self, _time: f64) -> bool {
        self.rng.bool_with(self.p)
    }
    fn rate_at(&mut self, _time: f64) -> f64 {
        // Nominal rate if sending at full speed is p·r; callers that need
        // a rate should use the rate-based processes instead.
        f64::NAN
    }
}

/// Adapter converting a rate-based process (λ losses/s) into a per-packet
/// Bernoulli with `p(t) = λ(t) / r_ref` — i.e. the loss fraction the
/// process would induce at the reference (full link) packet rate.
///
/// Lets the TCP/Globus baselines experience the *same* time-varying HMM
/// loss regime as the UDP protocols on a fair per-packet basis.
pub struct FractionOfRate<L: LossProcess> {
    pub inner: L,
    pub r_ref: f64,
    rng: Pcg64,
}

impl<L: LossProcess> FractionOfRate<L> {
    pub fn new(inner: L, r_ref: f64, seed: u64) -> Self {
        assert!(r_ref > 0.0);
        FractionOfRate { inner, r_ref, rng: Pcg64::seeded(seed) }
    }
}

impl<L: LossProcess> LossProcess for FractionOfRate<L> {
    fn is_lost(&mut self, time: f64) -> bool {
        let p = (self.inner.rate_at(time) / self.r_ref).clamp(0.0, 1.0);
        self.rng.bool_with(p)
    }
    fn rate_at(&mut self, time: f64) -> f64 {
        self.inner.rate_at(time)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_loss_never_drops() {
        let mut l = NoLoss;
        assert!(!(0..1000).any(|i| l.is_lost(i as f64 * 0.001)));
    }

    #[test]
    fn zero_rate_never_drops() {
        let mut l = StaticLoss::new(0.0, 1);
        assert!(!(0..1000).any(|i| l.is_lost(i as f64 * 0.001)));
    }

    #[test]
    fn observed_rate_matches_lambda_when_packets_fast() {
        // Packet rate 19144/s >> λ = 383/s: drop fraction ≈ λ/r = 2%.
        let lambda = 383.0;
        let r = 19144.0;
        let mut l = StaticLoss::new(lambda, 7);
        let n = 1_000_000;
        let lost = (0..n).filter(|&i| l.is_lost(i as f64 / r)).count();
        let frac = lost as f64 / n as f64;
        let expect = lambda / r;
        assert!(
            (frac - expect).abs() / expect < 0.05,
            "frac={frac} expect={expect}"
        );
    }

    #[test]
    fn loss_events_are_coalesced_when_packets_slow() {
        // Packet rate 10/s << λ = 1000/s: at most every packet drops
        // (queue cleared per drop), so drop fraction ≈ 1, not 100.
        let mut l = StaticLoss::new(1000.0, 9);
        let n = 10_000;
        let lost = (0..n).filter(|&i| l.is_lost(i as f64 / 10.0)).count();
        let frac = lost as f64 / n as f64;
        assert!(frac > 0.99, "frac={frac}");
    }

    #[test]
    fn deterministic_under_seed() {
        let mut a = StaticLoss::new(100.0, 42);
        let mut b = StaticLoss::new(100.0, 42);
        for i in 0..10_000 {
            let t = i as f64 * 0.0005;
            assert_eq!(a.is_lost(t), b.is_lost(t));
        }
    }

    #[test]
    fn bernoulli_fraction_matches_p() {
        let mut l = BernoulliLoss::new(0.02, 5);
        let n = 500_000;
        let lost = (0..n).filter(|&i| l.is_lost(i as f64)).count();
        let frac = lost as f64 / n as f64;
        assert!((frac - 0.02).abs() < 0.002, "frac={frac}");
    }

    #[test]
    fn fraction_of_rate_tracks_inner_rate() {
        // Static λ=383 at r_ref=19144 ⇒ p ≈ 2% regardless of call spacing.
        let inner = StaticLoss::new(383.0, 1);
        let mut l = FractionOfRate::new(inner, 19_144.0, 2);
        let n = 500_000;
        // Slow sender (calls far apart) still sees the 2% fraction.
        let lost = (0..n).filter(|&i| l.is_lost(i as f64 * 0.01)).count();
        let frac = lost as f64 / n as f64;
        assert!((frac - 0.02).abs() < 0.002, "frac={frac}");
    }
}
