//! Time-varying packet-loss model: 3-state Gaussian HMM over a
//! continuous-time Markov chain (paper §5.2.2).
//!
//! States low / medium / high with per-state Gaussian loss rates
//! (μ, σ) = (19, 2), (383, 40), (957, 100) losses/s. Holding times are
//! exponential with rate 0.04 (mean 25 s); on expiry the chain jumps to
//! one of the other two states uniformly, and a fresh λ is drawn from the
//! new state's Gaussian (truncated at 0). Within a holding period λ is
//! constant, so loss events are generated piecewise-homogeneously.

use super::loss::LossProcess;
use crate::util::{dist, Pcg64};

/// Parameters of one HMM state.
#[derive(Debug, Clone, Copy)]
pub struct HmmState {
    pub mu: f64,
    pub sigma: f64,
}

/// Configuration for the loss HMM.
#[derive(Debug, Clone)]
pub struct HmmConfig {
    pub states: Vec<HmmState>,
    /// CTMC holding-time rate (transitions/second), used for every state
    /// whose index is not covered by [`HmmConfig::hold_rates`].
    pub transition_rate: f64,
    /// Optional per-state holding-time rates. Empty = uniform
    /// `transition_rate` (the paper's symmetric 3-state chain); a
    /// Gilbert-Elliott channel needs asymmetric dwell times, so its good
    /// state holds far longer than its bad state.
    pub hold_rates: Vec<f64>,
    /// Initial state index.
    pub initial_state: usize,
}

impl Default for HmmConfig {
    /// The paper's setting: low (19, 2), medium (383, 40), high (957, 100),
    /// transition rate 0.04 (≈ every 25 s).
    fn default() -> Self {
        HmmConfig {
            states: vec![
                HmmState { mu: 19.0, sigma: 2.0 },
                HmmState { mu: 383.0, sigma: 40.0 },
                HmmState { mu: 957.0, sigma: 100.0 },
            ],
            transition_rate: 0.04,
            hold_rates: Vec::new(),
            initial_state: 0,
        }
    }
}

impl HmmConfig {
    /// Two-state Gilbert-Elliott channel tuned so that, observed at
    /// `rate` fragments/s, the stationary loss fraction is `mean_loss`
    /// and losses arrive in runs of mean length `burst_len` fragments.
    ///
    /// Construction: the bad state is near-total loss (λ_bad = 10·rate,
    /// σ = 0, so every fragment inside a bad dwell is lost) and dwells
    /// `burst_len / rate` seconds on average; the good state is lossless
    /// and dwells `burst_len · (1 − mean_loss) / (mean_loss · rate)`, so
    /// the fraction of time spent bad — hence the fraction of fragments
    /// lost — is `mean_loss`.
    pub fn gilbert_elliott(mean_loss: f64, burst_len: f64, rate: f64) -> Self {
        assert!((0.0..1.0).contains(&mean_loss) && mean_loss > 0.0);
        assert!(burst_len >= 1.0);
        assert!(rate > 0.0);
        let dwell_bad = burst_len / rate;
        let dwell_good = dwell_bad * (1.0 - mean_loss) / mean_loss;
        HmmConfig {
            states: vec![
                HmmState { mu: 0.0, sigma: 0.0 },         // good
                HmmState { mu: 10.0 * rate, sigma: 0.0 }, // bad
            ],
            transition_rate: 1.0 / dwell_bad,
            hold_rates: vec![1.0 / dwell_good, 1.0 / dwell_bad],
            initial_state: 0,
        }
    }

    /// Holding-time rate for state `i`.
    fn hold_rate(&self, i: usize) -> f64 {
        self.hold_rates.get(i).copied().unwrap_or(self.transition_rate)
    }
}

/// HMM-driven loss process.
#[derive(Debug, Clone)]
pub struct HmmLoss {
    cfg: HmmConfig,
    rng: Pcg64,
    state: usize,
    /// λ drawn for the current holding period.
    lambda: f64,
    /// Absolute end time of the current holding period.
    state_end: f64,
    /// Next pending loss event time (absolute).
    next_loss: f64,
    last_query: f64,
    /// Loss events expire after this long (see [`super::loss::StaticLoss`]).
    ttl: f64,
}

impl HmmLoss {
    /// Paper-literal semantics: loss events never expire.
    pub fn new(cfg: HmmConfig, seed: u64) -> Self {
        Self::with_ttl(cfg, seed, f64::INFINITY)
    }

    /// Loss events expire `ttl` seconds after they occur (protocol
    /// simulations use one packet service time, `1/r`).
    pub fn with_ttl(cfg: HmmConfig, seed: u64, ttl: f64) -> Self {
        assert!(!cfg.states.is_empty());
        assert!(cfg.initial_state < cfg.states.len());
        assert!(ttl > 0.0);
        let mut rng = Pcg64::seeded(seed);
        let state = cfg.initial_state;
        let lambda = Self::draw_lambda(&mut rng, cfg.states[state]);
        let state_end = dist::exponential(&mut rng, cfg.hold_rate(state));
        let mut s = HmmLoss {
            cfg,
            rng,
            state,
            lambda,
            state_end,
            next_loss: 0.0,
            last_query: 0.0,
            ttl,
        };
        s.next_loss = s.sample_next_loss(0.0);
        s
    }

    /// Paper default with a seed.
    pub fn paper_default(seed: u64) -> Self {
        Self::new(HmmConfig::default(), seed)
    }

    /// Paper default with loss-event expiry.
    pub fn paper_default_with_ttl(seed: u64, ttl: f64) -> Self {
        Self::with_ttl(HmmConfig::default(), seed, ttl)
    }

    fn draw_lambda(rng: &mut Pcg64, st: HmmState) -> f64 {
        dist::normal(rng, st.mu, st.sigma).max(0.0)
    }

    /// Jump to a uniformly-chosen *different* state.
    fn transition(&mut self, at: f64) {
        let n = self.cfg.states.len();
        let next = if n == 1 {
            0
        } else {
            let j = self.rng.range(0, n - 1);
            if j >= self.state {
                j + 1
            } else {
                j
            }
        };
        self.state = next;
        self.lambda = Self::draw_lambda(&mut self.rng, self.cfg.states[next]);
        self.state_end = at + dist::exponential(&mut self.rng, self.cfg.hold_rate(next));
    }

    /// Sample the next loss-event time from `from`, honouring state
    /// boundaries (piecewise-homogeneous thinning-free construction).
    fn sample_next_loss(&mut self, from: f64) -> f64 {
        let mut t = from;
        loop {
            if self.lambda <= 0.0 {
                // No losses in this state; skip to its end.
                t = self.state_end;
                self.transition(t);
                continue;
            }
            let gap = dist::exponential(&mut self.rng, self.lambda);
            if t + gap <= self.state_end {
                return t + gap;
            }
            // Crossed a state boundary: restart from it (memorylessness).
            t = self.state_end;
            self.transition(t);
        }
    }

    /// Advance the chain (without sampling losses) so `rate_at` reflects
    /// the state at `time`.
    fn advance_chain_to(&mut self, time: f64) {
        while time >= self.state_end {
            let at = self.state_end;
            self.transition(at);
            // The pending loss event was sampled under the old λ only up
            // to the boundary; if it lies beyond the boundary, resample
            // from the boundary under the new regime.
            if self.next_loss > at {
                self.next_loss = self.sample_next_loss(at);
            }
        }
    }

    /// Current state index (for tests / tracing).
    pub fn state(&self) -> usize {
        self.state
    }
}

impl LossProcess for HmmLoss {
    fn is_lost(&mut self, time: f64) -> bool {
        debug_assert!(time >= self.last_query - 1e-9);
        self.last_query = time;
        self.advance_chain_to(time);
        // Expire stale events.
        let horizon = time - self.ttl;
        while self.next_loss < horizon {
            self.next_loss = self.sample_next_loss(self.next_loss);
        }
        if time + 1e-15 < self.next_loss {
            return false;
        }
        self.next_loss = self.sample_next_loss(time);
        true
    }

    fn rate_at(&mut self, time: f64) -> f64 {
        self.advance_chain_to(time);
        self.lambda
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn states_change_over_time() {
        let mut h = HmmLoss::paper_default(1);
        let mut states = std::collections::HashSet::new();
        for i in 0..600 {
            h.rate_at(i as f64); // advance 10 minutes
            states.insert(h.state());
        }
        assert!(states.len() >= 2, "chain stuck: {states:?}");
    }

    #[test]
    fn mean_holding_time_near_25s() {
        let mut h = HmmLoss::paper_default(5);
        let mut transitions = 0;
        let mut prev = h.state();
        let horizon = 20_000.0;
        let mut t = 0.0;
        while t < horizon {
            h.rate_at(t);
            if h.state() != prev {
                transitions += 1;
                prev = h.state();
            }
            t += 0.5;
        }
        let mean_hold = horizon / transitions as f64;
        assert!(
            (20.0..32.0).contains(&mean_hold),
            "mean holding time {mean_hold}"
        );
    }

    #[test]
    fn lambda_tracks_state_gaussians() {
        let mut h = HmmLoss::paper_default(9);
        let mut t = 0.0;
        for _ in 0..2000 {
            let lam = h.rate_at(t);
            let st = h.state();
            let HmmState { mu, sigma } = HmmConfig::default().states[st];
            assert!(
                (lam - mu).abs() <= 6.0 * sigma,
                "state {st}: λ={lam} not near μ={mu}"
            );
            t += 5.0;
        }
    }

    #[test]
    fn loss_fraction_in_low_state_near_point1_percent() {
        // Pin to the low state by using a chain that never transitions.
        let cfg = HmmConfig {
            states: vec![HmmState { mu: 19.0, sigma: 0.0 }],
            transition_rate: 1e-12,
            hold_rates: Vec::new(),
            initial_state: 0,
        };
        let mut h = HmmLoss::new(cfg, 3);
        let r = 19144.0;
        let n = 2_000_000;
        let lost = (0..n).filter(|&i| h.is_lost(i as f64 / r)).count();
        let frac = lost as f64 / n as f64;
        let expect = 19.0 / r;
        assert!(
            (frac - expect).abs() / expect < 0.1,
            "frac={frac} expect={expect}"
        );
    }

    #[test]
    fn deterministic_under_seed() {
        let mut a = HmmLoss::paper_default(77);
        let mut b = HmmLoss::paper_default(77);
        for i in 0..100_000 {
            let t = i as f64 * 0.001;
            assert_eq!(a.is_lost(t), b.is_lost(t), "diverged at t={t}");
        }
    }
}
