//! CRC-32 (IEEE 802.3, reflected, the zlib/`crc32fast` polynomial) —
//! offline substitute for the `crc32fast` crate.
//!
//! Table-driven, one 256-entry table built at compile time. The wire
//! format appends this checksum to every datagram; throughput is far from
//! the hot path's bottleneck (the 4 KiB payload copy dominates).

/// Reflected IEEE polynomial.
const POLY: u32 = 0xEDB8_8320;

const fn build_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u32;
        let mut bit = 0;
        while bit < 8 {
            crc = if crc & 1 != 0 { (crc >> 1) ^ POLY } else { crc >> 1 };
            bit += 1;
        }
        table[i] = crc;
        i += 1;
    }
    table
}

static TABLE: [u32; 256] = build_table();

/// Streaming CRC-32 state (API-compatible with `crc32fast::Hasher` for
/// the subset Janus uses).
#[derive(Debug, Clone)]
pub struct Hasher {
    state: u32,
}

impl Hasher {
    pub fn new() -> Hasher {
        Hasher { state: 0xFFFF_FFFF }
    }

    pub fn update(&mut self, buf: &[u8]) {
        let mut crc = self.state;
        for &b in buf {
            crc = (crc >> 8) ^ TABLE[((crc ^ b as u32) & 0xFF) as usize];
        }
        self.state = crc;
    }

    pub fn finalize(self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

impl Default for Hasher {
    fn default() -> Self {
        Hasher::new()
    }
}

/// One-shot checksum.
pub fn crc32(buf: &[u8]) -> u32 {
    let mut h = Hasher::new();
    h.update(buf);
    h.finalize()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // Standard CRC-32 check values (zlib-compatible).
        assert_eq!(crc32(b""), 0x0000_0000);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
        assert_eq!(crc32(&[0u8]), 0xD202_EF8D);
    }

    #[test]
    fn streaming_matches_one_shot() {
        let data: Vec<u8> = (0..=255u8).cycle().take(10_000).collect();
        let mut h = Hasher::new();
        for chunk in data.chunks(37) {
            h.update(chunk);
        }
        assert_eq!(h.finalize(), crc32(&data));
    }

    #[test]
    fn detects_single_bit_flips() {
        let mut data = vec![0xA5u8; 512];
        let base = crc32(&data);
        for i in [0usize, 100, 511] {
            data[i] ^= 0x01;
            assert_ne!(crc32(&data), base, "flip at {i} undetected");
            data[i] ^= 0x01;
        }
        assert_eq!(crc32(&data), base);
    }
}
