//! Shared low-level utilities: PRNG, distributions, special functions,
//! statistics, and the mini property-testing layer.
//!
//! These stand in for `rand`, `statrs`, and `proptest`, none of which are
//! available in the offline vendored crate set (see DESIGN.md §3).

pub mod crc32;
pub mod dist;
pub mod err;
pub mod prng;
pub mod prop;
pub mod special;
pub mod stats;

pub use prng::Pcg64;
