//! Minimal error type + context plumbing (`anyhow` substitute).
//!
//! The offline vendored crate set has no `anyhow`, so this module carries
//! the slice of it Janus uses: a cheap string-backed [`Error`], a
//! [`Result`] alias, the [`anyhow!`]/[`bail!`] macros, and a [`Context`]
//! extension trait for `Result`/`Option`. Errors render their context
//! chain as `outer: inner` in both `{}` and `{:#}` (anyhow's `{:#}`
//! behaviour, which the failure-injection tests match against).

use std::fmt;

/// String-backed error with a context chain.
pub struct Error {
    msg: String,
}

/// Crate-wide result alias (drop-in for `anyhow::Result`).
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<D: fmt::Display>(msg: D) -> Error {
        Error { msg: msg.to_string() }
    }

    /// Prepend a context layer (`context: self`).
    pub fn wrap<D: fmt::Display>(self, context: D) -> Error {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Note: `Error` deliberately does NOT implement `std::error::Error`,
// which is what makes this blanket conversion coherent alongside the
// reflexive `From<Error> for Error` (the same trick anyhow uses).
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::util::err::Error::msg(format!($($arg)*))
    };
}

/// Early-return with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Attach context to errors (and to `None`), anyhow-style.
pub trait Context<T> {
    /// Wrap the error (or `None`) with a fixed context message.
    fn context<D: fmt::Display>(self, context: D) -> Result<T>;
    /// Wrap with a lazily-built context message.
    fn with_context<D: fmt::Display, F: FnOnce() -> D>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<D: fmt::Display>(self, context: D) -> Result<T> {
        self.map_err(|e| e.into().wrap(context))
    }
    fn with_context<D: fmt::Display, F: FnOnce() -> D>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<D: fmt::Display>(self, context: D) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }
    fn with_context<D: fmt::Display, F: FnOnce() -> D>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        let e = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        Err(e)? // exercises the blanket From<std::error::Error>
    }

    #[test]
    fn macro_and_display() {
        let e = anyhow!("bad value {}", 42);
        assert_eq!(format!("{e}"), "bad value 42");
        assert_eq!(format!("{e:#}"), "bad value 42");
        assert_eq!(format!("{e:?}"), "bad value 42");
    }

    #[test]
    fn bail_returns_early() {
        fn f(x: u32) -> Result<u32> {
            if x == 0 {
                bail!("zero input");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(format!("{}", f(0).unwrap_err()).contains("zero"));
    }

    #[test]
    fn context_chains_outer_to_inner() {
        let e = io_fail().context("reading manifest").unwrap_err();
        let s = format!("{e:#}");
        assert!(s.contains("reading manifest"), "{s}");
        assert!(s.contains("gone"), "{s}");
        assert!(s.find("reading").unwrap() < s.find("gone").unwrap());
    }

    #[test]
    fn with_context_is_lazy() {
        let ok: Result<u32> = Ok(7);
        let got = ok.with_context(|| panic!("must not run")).unwrap();
        assert_eq!(got, 7);
    }

    #[test]
    fn option_context() {
        let none: Option<u32> = None;
        let e = none.context("missing key").unwrap_err();
        assert!(format!("{e}").contains("missing key"));
        assert_eq!(Some(5).context("unused").unwrap(), 5);
    }

    #[test]
    fn question_mark_on_own_error() {
        fn inner() -> Result<()> {
            bail!("inner fault")
        }
        fn outer() -> Result<()> {
            inner()?;
            Ok(())
        }
        assert!(format!("{}", outer().unwrap_err()).contains("inner fault"));
    }
}
