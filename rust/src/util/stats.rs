//! Summary statistics used by the bench harness and experiment reports.

/// Arithmetic mean; 0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// p-th percentile (0..=100) via linear interpolation on a sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!((0.0..=100.0).contains(&p));
    if xs.is_empty() {
        return 0.0;
    }
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    if s.len() == 1 {
        return s[0];
    }
    let rank = p / 100.0 * (s.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    s[lo] * (1.0 - frac) + s[hi] * frac
}

/// Median.
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Median absolute deviation (robust spread), scaled to be consistent
/// with the standard deviation for normal data (×1.4826).
pub fn mad(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let med = median(xs);
    let devs: Vec<f64> = xs.iter().map(|x| (x - med).abs()).collect();
    1.4826 * median(&devs)
}

/// Min and max of a slice (NaN-free input assumed).
pub fn min_max(xs: &[f64]) -> (f64, f64) {
    xs.iter().fold((f64::INFINITY, f64::NEG_INFINITY), |(lo, hi), &x| {
        (lo.min(x), hi.max(x))
    })
}

/// A running summary: count, mean, variance (Welford), min, max.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    pub count: u64,
    mean: f64,
    m2: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary {
            count: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.count += 1;
        let delta = x - self.mean;
        self.mean += delta / self.count as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.mean
        }
    }

    pub fn variance(&self) -> f64 {
        if self.count < 2 {
            0.0
        } else {
            self.m2 / self.count as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stddev() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn mad_robust_to_outlier() {
        let xs = [1.0, 1.1, 0.9, 1.05, 0.95, 100.0];
        assert!(mad(&xs) < 1.0, "mad={}", mad(&xs));
    }

    #[test]
    fn summary_matches_batch() {
        let xs = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0];
        let mut s = Summary::new();
        for &x in &xs {
            s.push(x);
        }
        assert!((s.mean() - mean(&xs)).abs() < 1e-12);
        assert!((s.stddev() - stddev(&xs)).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 9.0);
        assert_eq!(s.count, 8);
    }

    #[test]
    fn empty_inputs_are_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(median(&[]), 0.0);
        assert_eq!(mad(&[]), 0.0);
        assert_eq!(Summary::new().mean(), 0.0);
    }
}
