//! Minimal property-based testing support.
//!
//! `proptest` is not in the offline vendored crate set, so this module
//! provides the slice of it Janus' invariant tests need: a seeded case
//! generator, a configurable number of cases, and greedy shrinking of
//! failing integer-vector inputs. Failures report the seed and the
//! shrunken input so they can be replayed.

use super::prng::Pcg64;

/// Configuration for a property run.
#[derive(Debug, Clone)]
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_iters: usize,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig {
            cases: 256,
            seed: 0xC0FFEE,
            max_shrink_iters: 2_000,
        }
    }
}

/// Run `property` against `cases` inputs produced by `gen`.
///
/// On failure, attempts to shrink the input with `shrink` (returns
/// candidate smaller inputs) and panics with the minimal reproduction.
pub fn check<T, G, S, P>(cfg: &PropConfig, mut gen: G, mut shrink: S, mut property: P)
where
    T: Clone + std::fmt::Debug,
    G: FnMut(&mut Pcg64) -> T,
    S: FnMut(&T) -> Vec<T>,
    P: FnMut(&T) -> Result<(), String>,
{
    let mut rng = Pcg64::seeded(cfg.seed);
    for case in 0..cfg.cases {
        let input = gen(&mut rng);
        if let Err(msg) = property(&input) {
            // Shrink: repeatedly take the first failing candidate.
            let mut best = input.clone();
            let mut best_msg = msg;
            let mut iters = 0;
            'outer: loop {
                if iters >= cfg.max_shrink_iters {
                    break;
                }
                for cand in shrink(&best) {
                    iters += 1;
                    if let Err(m) = property(&cand) {
                        best = cand;
                        best_msg = m;
                        continue 'outer;
                    }
                    if iters >= cfg.max_shrink_iters {
                        break;
                    }
                }
                break;
            }
            panic!(
                "property failed (seed={}, case={case}):\n  input: {best:?}\n  error: {best_msg}",
                cfg.seed
            );
        }
    }
}

/// Shrinker for `Vec<u64>`-like inputs: drop elements and halve values.
pub fn shrink_vec_u64(v: &Vec<u64>) -> Vec<Vec<u64>> {
    let mut out = Vec::new();
    // Remove halves, then single elements.
    if v.len() > 1 {
        out.push(v[..v.len() / 2].to_vec());
        out.push(v[v.len() / 2..].to_vec());
    }
    for i in 0..v.len().min(8) {
        let mut w = v.clone();
        w.remove(i);
        out.push(w);
    }
    // Halve each element.
    for i in 0..v.len().min(8) {
        if v[i] > 0 {
            let mut w = v.clone();
            w[i] /= 2;
            out.push(w);
        }
    }
    out
}

/// Shrinker that never shrinks (for inputs where shrinking is meaningless).
pub fn no_shrink<T: Clone>(_: &T) -> Vec<T> {
    Vec::new()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(
            &PropConfig::default(),
            |rng| rng.next_below(100),
            no_shrink,
            |&x| {
                if x < 100 {
                    Ok(())
                } else {
                    Err(format!("{x} >= 100"))
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(
            &PropConfig { cases: 64, ..Default::default() },
            |rng| rng.next_below(100),
            no_shrink,
            |&x| {
                if x < 50 {
                    Ok(())
                } else {
                    Err("too big".to_string())
                }
            },
        );
    }

    #[test]
    fn shrinking_finds_small_counterexample() {
        // Property: sum < 100. Failing inputs shrink toward minimal sum >= 100.
        let result = std::panic::catch_unwind(|| {
            check(
                &PropConfig { cases: 200, ..Default::default() },
                |rng| (0..10).map(|_| rng.next_below(50)).collect::<Vec<u64>>(),
                shrink_vec_u64,
                |v| {
                    let s: u64 = v.iter().sum();
                    if s < 100 {
                        Ok(())
                    } else {
                        Err(format!("sum={s}"))
                    }
                },
            )
        });
        let err = result.expect_err("property should fail");
        let msg = err.downcast_ref::<String>().expect("panic message");
        // The shrunken counterexample should be small (few elements).
        let input_line = msg.lines().find(|l| l.contains("input")).unwrap();
        let commas = input_line.matches(',').count();
        assert!(commas <= 4, "did not shrink: {input_line}");
    }
}
