//! Seedable PCG-family pseudo-random number generator.
//!
//! The vendored offline crate set does not include `rand`, so Janus ships
//! its own small, fast, statistically solid generator: PCG64 (XSL-RR
//! variant) with a 128-bit LCG state. Every stochastic component in the
//! simulator takes an explicit seed so experiments are reproducible.

/// PCG64 XSL-RR generator. 128-bit state, 64-bit output.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a 64-bit seed and stream id.
    ///
    /// Distinct `(seed, stream)` pairs yield independent sequences; the
    /// simulator derives per-process streams from a run seed.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg64 {
            state: 0,
            inc: ((stream as u128) << 1) | 1,
        };
        rng.next_u64();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.next_u64();
        rng
    }

    /// Convenience constructor with the default stream.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Derive a child generator for an independent component.
    ///
    /// Used to give each simulator process (loss model, link, control
    /// plane, ...) its own stream from one experiment seed.
    pub fn fork(&mut self, tag: u64) -> Pcg64 {
        let seed = self.next_u64() ^ tag.wrapping_mul(0x9e37_79b9_7f4a_7c15);
        Pcg64::new(seed, tag.wrapping_add(1))
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self
            .state
            .wrapping_mul(PCG_MULT)
            .wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in (0, 1]; safe as the argument of `ln`.
    #[inline]
    pub fn next_f64_open(&mut self) -> f64 {
        ((self.next_u64() >> 11) as f64 + 1.0) * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, bound)` using Lemire's rejection method.
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "next_below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(bound as u128);
        let mut lo = m as u64;
        if lo < bound {
            let threshold = bound.wrapping_neg() % bound;
            while lo < threshold {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(bound as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range");
        lo + self.next_below((hi - lo) as u64) as usize
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn bool_with(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fill a byte slice with random data.
    pub fn fill_bytes(&mut self, out: &mut [u8]) {
        let mut chunks = out.chunks_exact_mut(8);
        for c in &mut chunks {
            c.copy_from_slice(&self.next_u64().to_le_bytes());
        }
        let rem = chunks.into_remainder();
        if !rem.is_empty() {
            let b = self.next_u64().to_le_bytes();
            rem.copy_from_slice(&b[..rem.len()]);
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (Floyd's algorithm).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut chosen: Vec<usize> = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.next_below((j + 1) as u64) as usize;
            if chosen.contains(&t) {
                chosen.push(j);
            } else {
                chosen.push(t);
            }
        }
        chosen
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg64::seeded(42);
        let mut b = Pcg64::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::seeded(1);
        let mut b = Pcg64::seeded(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg64::seeded(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            let y = r.next_f64_open();
            assert!(y > 0.0 && y <= 1.0);
        }
    }

    #[test]
    fn uniform_mean_close_to_half() {
        let mut r = Pcg64::seeded(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn next_below_bounds_and_coverage() {
        let mut r = Pcg64::seeded(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.next_below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Pcg64::seeded(9);
        for _ in 0..100 {
            let k = r.range(0, 16) + 1;
            let idx = r.sample_indices(32, k);
            assert_eq!(idx.len(), k);
            let mut sorted = idx.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), k, "duplicates in {idx:?}");
            assert!(sorted.iter().all(|&i| i < 32));
        }
    }

    #[test]
    fn forked_streams_are_independent() {
        let mut root = Pcg64::seeded(5);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same <= 1);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::seeded(13);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fill_bytes_covers_tail() {
        let mut r = Pcg64::seeded(17);
        let mut buf = [0u8; 13];
        r.fill_bytes(&mut buf);
        assert!(buf.iter().any(|&b| b != 0));
    }
}
