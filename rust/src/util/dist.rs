//! Random-variate sampling for the simulator.
//!
//! The packet-loss process draws exponential inter-loss gaps (§5.2.2 of the
//! paper), the HMM state-holding times are exponential, and the per-state
//! loss rates are Gaussian. All samplers take an explicit [`Pcg64`].

use super::prng::Pcg64;

/// Exponential variate with rate `lambda` (mean `1/lambda`).
#[inline]
pub fn exponential(rng: &mut Pcg64, lambda: f64) -> f64 {
    assert!(lambda > 0.0, "exponential rate must be positive");
    -rng.next_f64_open().ln() / lambda
}

/// Standard normal variate via Marsaglia polar method.
pub fn standard_normal(rng: &mut Pcg64) -> f64 {
    loop {
        let u = 2.0 * rng.next_f64() - 1.0;
        let v = 2.0 * rng.next_f64() - 1.0;
        let s = u * u + v * v;
        if s > 0.0 && s < 1.0 {
            return u * (-2.0 * s.ln() / s).sqrt();
        }
    }
}

/// Gaussian variate with mean `mu` and standard deviation `sigma`.
#[inline]
pub fn normal(rng: &mut Pcg64, mu: f64, sigma: f64) -> f64 {
    mu + sigma * standard_normal(rng)
}

/// Poisson variate with mean `mu`.
///
/// Knuth multiplication for small means; normal approximation with
/// continuity correction above 64 (adequate for simulator use where large
/// means only appear in aggregate-loss draws).
pub fn poisson(rng: &mut Pcg64, mu: f64) -> u64 {
    assert!(mu >= 0.0);
    if mu == 0.0 {
        return 0;
    }
    if mu < 64.0 {
        let limit = (-mu).exp();
        let mut k = 0u64;
        let mut prod = rng.next_f64_open();
        while prod > limit {
            k += 1;
            prod *= rng.next_f64_open();
        }
        k
    } else {
        let x = normal(rng, mu, mu.sqrt()).round();
        if x < 0.0 {
            0
        } else {
            x as u64
        }
    }
}

/// Geometric number of Bernoulli(p) failures before the first success.
pub fn geometric(rng: &mut Pcg64, p: f64) -> u64 {
    assert!(p > 0.0 && p <= 1.0);
    if p >= 1.0 {
        return 0;
    }
    let u = rng.next_f64_open();
    (u.ln() / (1.0 - p).ln()).floor() as u64
}

/// Binomial(n, p) variate. Exact inversion for small n, else normal approx.
pub fn binomial(rng: &mut Pcg64, n: u64, p: f64) -> u64 {
    assert!((0.0..=1.0).contains(&p));
    if p == 0.0 || n == 0 {
        return 0;
    }
    if p == 1.0 {
        return n;
    }
    if n <= 256 {
        let mut count = 0;
        for _ in 0..n {
            if rng.bool_with(p) {
                count += 1;
            }
        }
        count
    } else {
        let mu = n as f64 * p;
        let sigma = (n as f64 * p * (1.0 - p)).sqrt();
        let x = normal(rng, mu, sigma).round();
        x.clamp(0.0, n as f64) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mean_var(xs: &[f64]) -> (f64, f64) {
        let n = xs.len() as f64;
        let m = xs.iter().sum::<f64>() / n;
        let v = xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / n;
        (m, v)
    }

    #[test]
    fn exponential_mean_matches_rate() {
        let mut r = Pcg64::seeded(1);
        let xs: Vec<f64> = (0..200_000).map(|_| exponential(&mut r, 4.0)).collect();
        let (m, _) = mean_var(&xs);
        assert!((m - 0.25).abs() < 0.005, "mean={m}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::seeded(2);
        let xs: Vec<f64> = (0..200_000).map(|_| normal(&mut r, 3.0, 2.0)).collect();
        let (m, v) = mean_var(&xs);
        assert!((m - 3.0).abs() < 0.03, "mean={m}");
        assert!((v - 4.0).abs() < 0.1, "var={v}");
    }

    #[test]
    fn poisson_small_mean() {
        let mut r = Pcg64::seeded(3);
        let xs: Vec<f64> = (0..100_000).map(|_| poisson(&mut r, 2.5) as f64).collect();
        let (m, v) = mean_var(&xs);
        assert!((m - 2.5).abs() < 0.05, "mean={m}");
        assert!((v - 2.5).abs() < 0.1, "var={v}");
    }

    #[test]
    fn poisson_large_mean_normal_path() {
        let mut r = Pcg64::seeded(4);
        let xs: Vec<f64> = (0..50_000).map(|_| poisson(&mut r, 200.0) as f64).collect();
        let (m, v) = mean_var(&xs);
        assert!((m - 200.0).abs() < 1.0, "mean={m}");
        assert!((v - 200.0).abs() < 10.0, "var={v}");
    }

    #[test]
    fn geometric_mean() {
        let mut r = Pcg64::seeded(5);
        let p = 0.2;
        let xs: Vec<f64> = (0..100_000).map(|_| geometric(&mut r, p) as f64).collect();
        let (m, _) = mean_var(&xs);
        let expect = (1.0 - p) / p; // failures before success
        assert!((m - expect).abs() < 0.1, "mean={m} expect={expect}");
    }

    #[test]
    fn binomial_exact_and_approx_agree_in_mean() {
        let mut r = Pcg64::seeded(6);
        let small: Vec<f64> = (0..50_000).map(|_| binomial(&mut r, 100, 0.3) as f64).collect();
        let (m, _) = mean_var(&small);
        assert!((m - 30.0).abs() < 0.3, "mean={m}");
        let big: Vec<f64> = (0..50_000).map(|_| binomial(&mut r, 10_000, 0.3) as f64).collect();
        let (mb, _) = mean_var(&big);
        assert!((mb - 3000.0).abs() < 5.0, "mean={mb}");
    }

    #[test]
    fn poisson_zero_mean_is_zero() {
        let mut r = Pcg64::seeded(7);
        assert_eq!(poisson(&mut r, 0.0), 0);
    }
}
