//! Special functions for the optimization models.
//!
//! Equations 4–7 of the paper involve Poisson pmfs and ratios of binomial
//! coefficients with arguments in the hundreds (`u = r·t + n − 1 ≈ 222` at
//! the paper's parameters). Everything is computed in log space via
//! `ln Γ` so the hypergeometric terms never overflow.

/// Natural log of the gamma function (Lanczos approximation, g = 7).
///
/// Accurate to ~1e-13 relative over the range used by the models.
pub fn ln_gamma(x: f64) -> f64 {
    assert!(x > 0.0, "ln_gamma domain: x > 0, got {x}");
    const COEF: [f64; 9] = [
        0.999_999_999_999_809_93,
        676.520_368_121_885_1,
        -1_259.139_216_722_402_8,
        771.323_428_777_653_1,
        -176.615_029_162_140_6,
        12.507_343_278_686_905,
        -0.138_571_095_265_720_12,
        9.984_369_578_019_572e-6,
        1.505_632_735_149_311_6e-7,
    ];
    if x < 0.5 {
        // Reflection formula.
        let pi = std::f64::consts::PI;
        return (pi / (pi * x).sin()).ln() - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = COEF[0];
    let t = x + 7.5;
    for (i, &c) in COEF.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

/// Size of the precomputed ln-factorial table. Covers every `u = r·t+n−1`
/// the models see at paper-scale parameters with lots of headroom.
const LN_FACT_TABLE: usize = 8_192;

fn ln_fact_table() -> &'static [f64] {
    use std::sync::OnceLock;
    static TABLE: OnceLock<Vec<f64>> = OnceLock::new();
    TABLE.get_or_init(|| {
        let mut t = Vec::with_capacity(LN_FACT_TABLE);
        let mut acc = 0.0f64;
        t.push(0.0); // ln 0! = 0
        for n in 1..LN_FACT_TABLE {
            acc += (n as f64).ln();
            t.push(acc);
        }
        t
    })
}

/// ln n! — table lookup below 8 192 (the models' hot path), `ln Γ` above.
#[inline]
pub fn ln_factorial(n: u64) -> f64 {
    if (n as usize) < LN_FACT_TABLE {
        ln_fact_table()[n as usize]
    } else {
        ln_gamma(n as f64 + 1.0)
    }
}

/// ln C(n, k); `-inf` when the coefficient is zero (k > n).
#[inline]
pub fn ln_binomial(n: u64, k: u64) -> f64 {
    if k > n {
        return f64::NEG_INFINITY;
    }
    ln_factorial(n) - ln_factorial(k) - ln_factorial(n - k)
}

/// Poisson pmf `P(X = k)` with mean `mu`, computed in log space.
#[inline]
pub fn poisson_pmf(k: u64, mu: f64) -> f64 {
    assert!(mu >= 0.0);
    if mu == 0.0 {
        return if k == 0 { 1.0 } else { 0.0 };
    }
    (k as f64 * mu.ln() - mu - ln_factorial(k)).exp()
}

/// Poisson upper tail `P(X > m)` with mean `mu`.
pub fn poisson_sf(m: u64, mu: f64) -> f64 {
    // 1 - CDF(m): sum the pmf while it is non-negligible.
    let mut cdf = 0.0;
    for k in 0..=m {
        cdf += poisson_pmf(k, mu);
    }
    (1.0 - cdf).clamp(0.0, 1.0)
}

/// Hypergeometric pmf: drawing `j` marked items out of `u` total of which
/// `n` are special, probability exactly `w` of the marked fall in the
/// special set: `C(n,w) C(u-n, j-w) / C(u, j)`.
pub fn hypergeometric_pmf(u: u64, n: u64, j: u64, w: u64) -> f64 {
    if w > n || w > j || j.saturating_sub(w) > u.saturating_sub(n) || j > u {
        return 0.0;
    }
    (ln_binomial(n, w) + ln_binomial(u - n, j - w) - ln_binomial(u, j)).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ln_gamma_known_values() {
        // Γ(1)=1, Γ(2)=1, Γ(5)=24, Γ(0.5)=√π
        assert!((ln_gamma(1.0)).abs() < 1e-12);
        assert!((ln_gamma(2.0)).abs() < 1e-12);
        assert!((ln_gamma(5.0) - 24f64.ln()).abs() < 1e-10);
        let half = std::f64::consts::PI.sqrt().ln();
        assert!((ln_gamma(0.5) - half).abs() < 1e-10);
    }

    #[test]
    fn ln_binomial_small_exact() {
        for n in 0..20u64 {
            let mut row = vec![1u64];
            for _ in 0..n {
                let mut next = vec![1u64];
                for w in row.windows(2) {
                    next.push(w[0] + w[1]);
                }
                next.push(1);
                row = next;
            }
            for (k, &exact) in row.iter().enumerate() {
                let approx = ln_binomial(n, k as u64).exp();
                assert!(
                    (approx - exact as f64).abs() / (exact as f64) < 1e-9,
                    "C({n},{k}) = {exact}, got {approx}"
                );
            }
        }
    }

    #[test]
    fn ln_binomial_out_of_range_is_zero() {
        assert_eq!(ln_binomial(5, 6), f64::NEG_INFINITY);
        assert_eq!(ln_binomial(5, 6).exp(), 0.0);
    }

    #[test]
    fn poisson_pmf_sums_to_one() {
        for &mu in &[0.1, 1.0, 5.0, 50.0] {
            let total: f64 = (0..(mu as u64 * 4 + 40)).map(|k| poisson_pmf(k, mu)).sum();
            assert!((total - 1.0).abs() < 1e-9, "mu={mu} total={total}");
        }
    }

    #[test]
    fn poisson_sf_complements_cdf() {
        let mu = 3.0;
        for m in 0..10u64 {
            let cdf: f64 = (0..=m).map(|k| poisson_pmf(k, mu)).sum();
            assert!((poisson_sf(m, mu) - (1.0 - cdf)).abs() < 1e-9);
        }
    }

    #[test]
    fn hypergeometric_sums_to_one() {
        let (u, n, j) = (222, 32, 10);
        let total: f64 = (0..=j).map(|w| hypergeometric_pmf(u, n, j, w)).sum();
        assert!((total - 1.0).abs() < 1e-9, "total={total}");
    }

    #[test]
    fn hypergeometric_known_small_case() {
        // Urn: 5 special of 10, draw 4, P(exactly 2 special)
        // = C(5,2)C(5,2)/C(10,4) = 10*10/210
        let p = hypergeometric_pmf(10, 5, 4, 2);
        assert!((p - 100.0 / 210.0).abs() < 1e-12);
    }

    #[test]
    fn hypergeometric_impossible_cases_zero() {
        assert_eq!(hypergeometric_pmf(10, 5, 4, 6), 0.0); // w > j
        assert_eq!(hypergeometric_pmf(10, 5, 8, 1), 0.0); // j-w > u-n
    }
}
