//! RS-vs-fountain duration matrix on a virtual clock: the same payload
//! through both erasure backends across loss {1%, 5%, 20%} × one-way
//! latency {2 ms, 50 ms}, plus a Gilbert-Elliott burst scenario. The
//! pass-barrier RS pipeline pays ≥1 RTT per retransmission pass; the
//! rateless fountain streams repair symbols ack-gated with no barrier,
//! so its completion time is RTT-additive, not RTT-multiplicative. The
//! virtual clock makes every duration a pure function of (seed, config)
//! — no wall-time noise. Emits
//! `target/bench-results/BENCH_fountain.json` (uploaded by CI) and
//! gates: fountain must beat RS at 5% loss on the high-RTT path.

use janus::api::{AdaptConfig, Contract};
use janus::coordinator::packet::is_fragment;
use janus::coordinator::{ReceiverConfig, SenderConfig};
use janus::engine::{ReceiverMachine, SenderMachine};
use janus::erasure::Backend;
use janus::metrics::bench::{bench_scale, BenchTable};
use janus::model::NetParams;
use janus::testkit::LossTrace;
use janus::util::Pcg64;
use std::collections::VecDeque;
use std::io::Write;
use std::path::PathBuf;
use std::time::{Duration, Instant};

const RATE: f64 = 200_000.0;
const BURST: f64 = 8.0;

/// Minimal deterministic two-pipe network (the engine_sm harness, sans
/// reordering): settable one-way latency, ordinal loss trace on the
/// fragment/repair path, reliable control datagrams.
struct Net {
    now: Instant,
    latency: Duration,
    s2r: VecDeque<(Instant, Vec<u8>)>,
    r2s: VecDeque<(Instant, Vec<u8>)>,
    trace: LossTrace,
    frag_tick: u64,
}

impl Net {
    fn new(latency: Duration, trace: LossTrace) -> Net {
        Net {
            now: Instant::now(),
            latency,
            s2r: VecDeque::new(),
            r2s: VecDeque::new(),
            trace,
            frag_tick: 0,
        }
    }

    fn send_s2r(&mut self, buf: &[u8]) {
        if is_fragment(buf) {
            let tick = self.frag_tick;
            self.frag_tick += 1;
            if self.trace.drop_at(tick) {
                return;
            }
        }
        self.s2r.push_back((self.now + self.latency, buf.to_vec()));
    }

    fn due(q: &mut VecDeque<(Instant, Vec<u8>)>, now: Instant) -> Vec<Vec<u8>> {
        let mut out = Vec::new();
        while let Some(&(at, _)) = q.front() {
            if at > now {
                break;
            }
            out.push(q.pop_front().unwrap().1);
        }
        out
    }

    fn next_arrival(&self) -> Option<Instant> {
        self.s2r.front().iter().chain(self.r2s.front().iter()).map(|&&(at, _)| at).min()
    }
}

fn pump(net: &mut Net, s: &mut SenderMachine, r: &mut ReceiverMachine) -> f64 {
    let start = net.now;
    let mut out = Vec::new();
    let mut steps = 0u64;
    while !(s.is_finished() && r.is_finished()) {
        steps += 1;
        assert!(steps < 50_000_000, "bench harness stalled");
        let now = net.now;
        let mut progressed = false;
        for buf in Net::due(&mut net.s2r, now) {
            r.handle_datagram(&buf, now);
            progressed = true;
        }
        for buf in Net::due(&mut net.r2s, now) {
            s.handle_datagram(&buf, now);
            progressed = true;
        }
        while s.poll_transmit(&mut out, now) {
            net.send_s2r(&out);
            progressed = true;
        }
        while r.poll_transmit(&mut out, now) {
            net.r2s.push_back((now + net.latency, out.clone()));
            progressed = true;
        }
        if progressed {
            continue;
        }
        let mut next = net.next_arrival();
        for cand in [s.poll_timeout(), r.poll_timeout()] {
            next = match (next, cand) {
                (Some(a), Some(b)) => Some(a.min(b)),
                (a, b) => a.or(b),
            };
        }
        let next = next.expect("bench harness: idle with no pending event");
        net.now = next.max(now + Duration::from_nanos(100));
        s.handle_timeout(net.now);
        r.handle_timeout(net.now);
    }
    net.now.saturating_duration_since(start).as_secs_f64()
}

fn payload(total: usize) -> Vec<Vec<u8>> {
    let mut rng = Pcg64::seeded(0xF0A7);
    [total / 4, total * 3 / 4]
        .iter()
        .map(|&sz| {
            let mut v = vec![0u8; sz.max(1)];
            rng.fill_bytes(&mut v);
            v
        })
        .collect()
}

struct Outcome {
    scenario: String,
    backend: &'static str,
    loss: f64,
    rtt_ms: f64,
    virt_s: f64,
    fragments: u64,
    passes: u32,
}

fn run_one(
    scenario: &str,
    backend: Backend,
    data: &[Vec<u8>],
    loss: f64,
    latency: Duration,
    trace: LossTrace,
) -> Outcome {
    let scfg = SenderConfig {
        net: NetParams { t: latency.as_secs_f64(), r: RATE, lambda: 0.0, n: 32, s: 1024 },
        contract: Contract::Fidelity(1e-7),
        initial_lambda: loss * RATE,
        max_duration: Duration::from_secs(600),
        plane_cuts: vec![],
        adapt: AdaptConfig::fixed(),
    };
    let rcfg = ReceiverConfig {
        t_w: 1e9,
        idle_timeout: Duration::from_secs(300),
        max_duration: Duration::from_secs(600),
    };
    let eps = vec![1e-3, 1e-7];
    let mut net = Net::new(latency, trace);
    let mut s = SenderMachine::with_backend(&scfg, data, &eps, backend, net.now)
        .expect("sender machine");
    let mut r = ReceiverMachine::new(&rcfg, net.now);
    let virt_s = pump(&mut net, &mut s, &mut r);
    assert!(!s.is_failed() && !r.is_failed(), "{scenario}: transfer failed");
    let sr = s.into_report().expect("sender report");
    let rr = r.into_report().expect("receiver report");
    for (li, want) in data.iter().enumerate() {
        assert_eq!(
            rr.levels[li].as_deref(),
            Some(&want[..]),
            "{scenario}: level {li} bytes differ"
        );
    }
    Outcome {
        scenario: scenario.to_string(),
        backend: if backend == Backend::Fountain { "fountain" } else { "rs" },
        loss,
        rtt_ms: 2.0 * latency.as_secs_f64() * 1e3,
        virt_s,
        fragments: sr.fragments_sent,
        passes: sr.passes,
    }
}

fn main() {
    // Default ≈ 1.2 MB of payload; JANUS_SCALE=1 runs ~12 MB.
    let scale = bench_scale(10);
    let data = payload(12 * 1024 * 1024 / scale as usize);

    let mut outcomes: Vec<Outcome> = Vec::new();
    for &(rtt_name, latency) in
        &[("lan", Duration::from_millis(2)), ("wan", Duration::from_millis(50))]
    {
        for &loss in &[0.01, 0.05, 0.20] {
            for backend in [Backend::Rs, Backend::Fountain] {
                let seed = 0x5EED ^ (((loss * 1e3) as u64) << 8);
                let name = format!("{rtt_name}_{:.0}pct", loss * 100.0);
                outcomes.push(run_one(
                    &name,
                    backend,
                    &data,
                    loss,
                    latency,
                    LossTrace::seeded(loss, seed),
                ));
            }
        }
        // Same mean loss arriving in bursts — the shape that defeats
        // per-group parity but not a rateless stream.
        for backend in [Backend::Rs, Backend::Fountain] {
            outcomes.push(run_one(
                &format!("{rtt_name}_ge_burst"),
                backend,
                &data,
                0.05,
                latency,
                LossTrace::gilbert_elliott(0.05, BURST, RATE, 0x6E0B),
            ));
        }
    }

    let mut table = BenchTable::new(
        "fountain",
        vec!["scenario", "backend", "virt_s", "fragments", "passes"],
    );
    table.header();
    for o in &outcomes {
        table.row(
            o.scenario.clone(),
            vec![
                o.backend.to_string(),
                format!("{:.4}", o.virt_s),
                format!("{}", o.fragments),
                format!("{}", o.passes),
            ],
        );
    }
    table.save().unwrap();
    write_json(&outcomes).expect("write BENCH_fountain.json");

    // --- Acceptance gate (ISSUE 9): barrier-free repair must win where
    // barriers are expensive — 5% loss on the 100 ms-RTT path.
    let pick = |scenario: &str, backend: &str| {
        outcomes
            .iter()
            .find(|o| o.scenario == scenario && o.backend == backend)
            .unwrap_or_else(|| panic!("missing {scenario}/{backend}"))
    };
    let rs_wan = pick("wan_5pct", "rs");
    let ft_wan = pick("wan_5pct", "fountain");
    assert!(
        ft_wan.virt_s < rs_wan.virt_s,
        "fountain ({:.4}s) must beat RS ({:.4}s) at 5% loss over a 100 ms RTT",
        ft_wan.virt_s,
        rs_wan.virt_s
    );
    assert_eq!(ft_wan.passes, 0, "fountain never takes a retransmission pass");
    println!(
        "\nwan 5%: fountain {:.4}s vs rs {:.4}s ({} passes) — barrier-free repair wins {:.1}x",
        ft_wan.virt_s,
        rs_wan.virt_s,
        rs_wan.passes,
        rs_wan.virt_s / ft_wan.virt_s
    );
    println!("fountain_throughput complete.");
}

/// Save the matrix as JSON (CI uploads this artifact as `BENCH_fountain`).
fn write_json(outcomes: &[Outcome]) -> std::io::Result<PathBuf> {
    let dir = PathBuf::from("target/bench-results");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join("BENCH_fountain.json");
    let mut f = std::fs::File::create(&path)?;
    writeln!(f, "{{")?;
    writeln!(f, "  \"bench\": \"fountain\",")?;
    writeln!(f, "  \"nominal_rate\": {RATE},")?;
    writeln!(f, "  \"burst_len\": {BURST},")?;
    writeln!(f, "  \"scenarios\": [")?;
    for (i, o) in outcomes.iter().enumerate() {
        writeln!(f, "    {{")?;
        writeln!(f, "      \"name\": \"{}\",", o.scenario)?;
        writeln!(f, "      \"backend\": \"{}\",", o.backend)?;
        writeln!(f, "      \"loss\": {},", o.loss)?;
        writeln!(f, "      \"rtt_ms\": {:.1},", o.rtt_ms)?;
        writeln!(f, "      \"virtual_s\": {:.6},", o.virt_s)?;
        writeln!(f, "      \"fragments\": {},", o.fragments)?;
        writeln!(f, "      \"passes\": {}", o.passes)?;
        writeln!(f, "    }}{}", if i + 1 < outcomes.len() { "," } else { "" })?;
    }
    writeln!(f, "  ]")?;
    writeln!(f, "}}")?;
    println!("json -> {}", path.display());
    Ok(path)
}
