//! Whole-stack performance profile (EXPERIMENTS.md §Perf).
//!
//! Measures each hot path in isolation so regressions are attributable:
//!   * simulator fragment throughput (per-packet loop incl. loss draws);
//!   * TCP event-engine throughput;
//!   * Eq. 8 / Eq. 12 solver latency;
//!   * GF(256) slice kernel bandwidth (scalar vs SIMD dispatch);
//!   * wire-format encode/decode rate;
//!   * end-to-end mem-transport datapath: the legacy Vec-per-fragment
//!     loop vs the pooled frame/arena loop (ISSUE 3 gate, saved to
//!     `target/bench-results/BENCH_datapath.json`).

use janus::coordinator::arena::FtgArena;
use janus::coordinator::packet::{encode_fragment_into, FragmentHeader, Packet, PacketView};
use janus::erasure::gf256::MulTable;
use janus::erasure::kernel;
use janus::erasure::RsCode;
use janus::metrics::bench::{bench_scale, time_it, BenchTable};
use janus::model::{
    optimize_deadline_paper, optimize_parity, LevelSchedule, NetParams,
};
use janus::sim::{run_guaranteed_error, run_tcp, BernoulliLoss, ParityPolicy, StaticLoss};
use janus::transport::channel::{mem_pair, Datagram, MemChannel};
use std::collections::HashMap;
use std::io::Write;
use std::path::PathBuf;
use std::time::Duration;

/// Datapath bench geometry — the paper's (k, m) = (28, 4), s = 4 KiB.
const DP_K: usize = 28;
const DP_M: usize = 4;
const DP_S: usize = 4096;
const DP_GROUPS: u32 = 64;

/// The pre-change steady state, reproduced with the surviving Vec
/// primitives: per-FTG `Vec` slicing (k+m+2 allocations), the
/// allocating `recv_timeout` (exact-size `Vec` per datagram, like the
/// old mpsc hand-off), owning `Packet::decode` (payload `to_vec`), and
/// a `Vec<Option<Vec<u8>>>` group table rebuilt per round (the old
/// table allocated per group + per fragment). Both paths run over the
/// same pooled `MemChannel`, so the measured delta is the datapath
/// primitives, not the channel. Returns fragments moved.
fn legacy_round(
    code: &RsCode,
    tx: &mut MemChannel,
    rx: &mut MemChannel,
    data: &[u8],
    out: &mut Vec<u8>,
) -> u64 {
    let mut groups: HashMap<(u8, u32), Vec<Option<Vec<u8>>>> = HashMap::new();
    let mut moved = 0u64;
    for ftg in 0..DP_GROUPS {
        let mut frags: Vec<Vec<u8>> = Vec::with_capacity(DP_K + DP_M);
        for i in 0..DP_K {
            let mut f = data[i * DP_S..(i + 1) * DP_S].to_vec();
            f.resize(DP_S, 0);
            frags.push(f);
        }
        let refs: Vec<&[u8]> = frags.iter().map(|f| f.as_slice()).collect();
        let parity = code.encode(&refs).expect("encode");
        frags.extend(parity);
        for (idx, frag) in frags.iter().enumerate() {
            let hdr = frag_header(ftg, idx);
            encode_fragment_into(&hdr, frag, out);
            tx.send(out);
        }
        for _ in 0..DP_K + DP_M {
            let buf = rx.recv_timeout(Duration::from_millis(500)).expect("fragment");
            if let Ok(Packet::Fragment(h, payload)) = Packet::decode(&buf) {
                let g = groups
                    .entry((h.level, h.ftg))
                    .or_insert_with(|| vec![None; DP_K + DP_M]);
                let idx = h.index as usize;
                if g[idx].is_none() {
                    g[idx] = Some(payload);
                }
                moved += 1;
            }
        }
    }
    moved
}

/// The pooled frame/arena steady state: reused send arena +
/// `encode_strided`, pooled frames through the channel, `recv_into`,
/// borrowing `PacketView` decode, one payload copy into the group arena.
#[allow(clippy::too_many_arguments)]
fn arena_round(
    code: &RsCode,
    tx: &mut MemChannel,
    rx: &mut MemChannel,
    data: &[u8],
    out: &mut Vec<u8>,
    send_arena: &mut FtgArena,
    groups: &mut HashMap<(u8, u32), FtgArena>,
    rbuf: &mut [u8],
) -> u64 {
    let mut moved = 0u64;
    for ftg in 0..DP_GROUPS {
        send_arena.reset(DP_K as u8, DP_M as u8, DP_S);
        for i in 0..DP_K {
            send_arena.slot_mut(i).copy_from_slice(&data[i * DP_S..(i + 1) * DP_S]);
        }
        send_arena.encode_parity(code).expect("encode");
        for idx in 0..send_arena.slots() {
            let hdr = frag_header(ftg, idx);
            encode_fragment_into(&hdr, send_arena.slot(idx), out);
            tx.send(out);
        }
        for _ in 0..DP_K + DP_M {
            let n = rx.recv_into(rbuf, Duration::from_millis(500)).expect("fragment");
            if let Ok(PacketView::Fragment(view)) = PacketView::decode(&rbuf[..n]) {
                let h = view.header;
                let g = groups
                    .entry((h.level, h.ftg))
                    .or_insert_with(|| FtgArena::new(h.k, h.m, DP_S));
                g.insert(h.index as usize, view.payload);
                moved += 1;
            }
        }
    }
    // Steady state re-receives the same group ids next round.
    for g in groups.values_mut() {
        g.reset(DP_K as u8, DP_M as u8, DP_S);
    }
    moved
}

fn frag_header(ftg: u32, idx: usize) -> FragmentHeader {
    FragmentHeader {
        level: 0,
        stream: 0,
        ftg,
        index: idx as u8,
        k: DP_K as u8,
        m: DP_M as u8,
        seq: 0,
        pass: 0,
    }
}

/// Save the datapath gate numbers as JSON (CI uploads this artifact).
fn write_datapath_json(
    legacy_frag_s: f64,
    arena_frag_s: f64,
    fragments: u64,
) -> std::io::Result<PathBuf> {
    let dir = PathBuf::from("target/bench-results");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join("BENCH_datapath.json");
    let mut f = std::fs::File::create(&path)?;
    let speedup = arena_frag_s / legacy_frag_s;
    writeln!(f, "{{")?;
    writeln!(f, "  \"bench\": \"datapath\",")?;
    writeln!(f, "  \"fragment_size_bytes\": {DP_S},")?;
    writeln!(f, "  \"k\": {DP_K},")?;
    writeln!(f, "  \"m\": {DP_M},")?;
    writeln!(f, "  \"fragments_per_path\": {fragments},")?;
    writeln!(f, "  \"legacy_frag_per_s\": {legacy_frag_s:.1},")?;
    writeln!(f, "  \"arena_frag_per_s\": {arena_frag_s:.1},")?;
    writeln!(
        f,
        "  \"arena_gbytes_per_s\": {:.3},",
        arena_frag_s * DP_S as f64 / 1e9
    )?;
    writeln!(f, "  \"speedup\": {speedup:.3}")?;
    writeln!(f, "}}")?;
    println!("[saved {}]", path.display());
    Ok(path)
}

fn main() {
    let mut table = BenchTable::new("perf_profile", vec!["path", "metric", "value"]);
    table.header();

    // --- Simulator fragment loop ---
    let params = NetParams::paper_default(383.0);
    let sched = LevelSchedule::paper_nyx_scaled(4); // ~1.8 M fragments
    let frags_est = (sched.total_bytes(4).div_ceil(4096)) as f64 * 32.0 / 28.0;
    let (res, secs) = time_it(|| {
        let mut loss = StaticLoss::with_ttl(383.0, 1, 1.0 / params.r);
        run_guaranteed_error(&mut loss, &params, &sched, 4, &ParityPolicy::Static(4))
    });
    table.row(
        "sim fragment loop",
        vec![
            "Mfrag/s".into(),
            format!("{:.1}", res.fragments_sent as f64 / secs / 1e6),
        ],
    );
    let _ = frags_est;

    // --- TCP event engine ---
    let (tcp, secs) = time_it(|| {
        let mut loss = BernoulliLoss::new(0.02, 2);
        run_tcp(&mut loss, &params, 512 * 1024 * 1024)
    });
    table.row(
        "tcp event engine",
        vec![
            "Mpkt/s".into(),
            format!("{:.2}", tcp.packets_sent as f64 / secs / 1e6),
        ],
    );

    // --- Solvers ---
    let bytes = LevelSchedule::paper_nyx().total_bytes(4);
    let (_, secs) = time_it(|| {
        for _ in 0..20 {
            std::hint::black_box(optimize_parity(&params, bytes));
        }
    });
    table.row("Eq.8 solve", vec!["ms".into(), format!("{:.2}", secs / 20.0 * 1e3)]);
    let full = LevelSchedule::paper_nyx();
    let (_, secs) = time_it(|| {
        for _ in 0..5 {
            std::hint::black_box(optimize_deadline_paper(&params, &full, 401.11));
        }
    });
    table.row("Eq.12 exhaustive solve", vec!["ms".into(), format!("{:.2}", secs / 5.0 * 1e3)]);

    // --- GF(256) slice kernel ---
    let t = MulTable::new(0xC7);
    let x = vec![0x5Au8; 4096];
    let mut y = vec![0u8; 4096];
    let reps = 200_000;
    let (_, secs) = time_it(|| {
        for _ in 0..reps {
            t.mul_slice_add(&x, &mut y);
            std::hint::black_box(&y);
        }
    });
    table.row(
        "gf256 mul_slice_add",
        vec![
            "GB/s".into(),
            format!("{:.2}", reps as f64 * 4096.0 / secs / 1e9),
        ],
    );
    // Same kernel on every supported tier (dispatch-once makes the
    // default row above whatever `best_supported` resolves to; these
    // rows make scalar/SSSE3/AVX2 deltas attributable).
    for tier in kernel::supported_tiers() {
        let (_, secs) = time_it(|| {
            for _ in 0..reps {
                t.mul_slice_add_tier(&x, &mut y, tier);
                std::hint::black_box(&y);
            }
        });
        table.row(
            format!("gf256 mul_slice_add [{}]", tier.name()),
            vec![
                "GB/s".into(),
                format!("{:.2}", reps as f64 * 4096.0 / secs / 1e9),
            ],
        );
    }

    // --- Wire format ---
    let hdr = FragmentHeader { level: 1, stream: 0, ftg: 9, index: 3, k: 28, m: 4, seq: 77, pass: 0 };
    let payload = vec![0xABu8; 4096];
    let mut out = Vec::with_capacity(4200);
    let reps = 300_000;
    let (_, secs) = time_it(|| {
        for _ in 0..reps {
            encode_fragment_into(&hdr, &payload, &mut out);
            std::hint::black_box(&out);
        }
    });
    table.row(
        "fragment encode",
        vec!["Mfrag/s".into(), format!("{:.2}", reps as f64 / secs / 1e6)],
    );
    let encoded = out.clone();
    let (_, secs) = time_it(|| {
        for _ in 0..reps {
            std::hint::black_box(Packet::decode(&encoded).unwrap());
        }
    });
    table.row(
        "fragment decode",
        vec!["Mfrag/s".into(), format!("{:.2}", reps as f64 / secs / 1e6)],
    );

    // --- End-to-end mem-transport datapath (ISSUE 3 gate) ---
    // Full chain both ways: slice → RS parity → wire encode → channel →
    // decode → group store. `JANUS_SCALE` shrinks the workload for CI
    // smoke runs.
    let rounds = (200 / bench_scale(10)).max(3);
    let code = RsCode::new(DP_K, DP_M).unwrap();
    let data: Vec<u8> = (0..DP_K * DP_S).map(|i| (i * 31 % 251) as u8).collect();
    let mut out = Vec::with_capacity(DP_S + 64);

    let (mut tx, mut rx) = mem_pair();
    legacy_round(&code, &mut tx, &mut rx, &data, &mut out); // warm-up
    let (legacy_frags, secs) = time_it(|| {
        let mut moved = 0u64;
        for _ in 0..rounds {
            moved += legacy_round(&code, &mut tx, &mut rx, &data, &mut out);
        }
        moved
    });
    let legacy_rate = legacy_frags as f64 / secs;
    table.row(
        "datapath legacy (Vec)",
        vec!["Mfrag/s".into(), format!("{:.3}", legacy_rate / 1e6)],
    );

    let (mut tx, mut rx) = mem_pair();
    let mut send_arena = FtgArena::new(DP_K as u8, DP_M as u8, DP_S);
    let mut groups: HashMap<(u8, u32), FtgArena> = HashMap::new();
    let mut rbuf = vec![0u8; janus::coordinator::packet::MAX_DATAGRAM];
    arena_round(
        &code, &mut tx, &mut rx, &data, &mut out, &mut send_arena, &mut groups, &mut rbuf,
    ); // warm-up
    let (arena_frags, secs) = time_it(|| {
        let mut moved = 0u64;
        for _ in 0..rounds {
            moved += arena_round(
                &code, &mut tx, &mut rx, &data, &mut out, &mut send_arena, &mut groups,
                &mut rbuf,
            );
        }
        moved
    });
    let arena_rate = arena_frags as f64 / secs;
    table.row(
        "datapath arena (pooled)",
        vec!["Mfrag/s".into(), format!("{:.3}", arena_rate / 1e6)],
    );
    let speedup = arena_rate / legacy_rate;
    table.row("datapath speedup", vec!["x".into(), format!("{speedup:.2}")]);
    assert_eq!(legacy_frags, arena_frags, "both paths must move the same load");
    write_datapath_json(legacy_rate, arena_rate, arena_frags).unwrap();
    // Smoke floor well under the ≥2× steady-state target so a noisy CI
    // runner cannot flake the gate; the JSON records the real ratio.
    assert!(
        speedup >= 1.2,
        "zero-allocation datapath regressed: {speedup:.2}x vs legacy (target ≥2x)"
    );

    table.save().unwrap();
    println!("\nperf_profile complete.");
}
