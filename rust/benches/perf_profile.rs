//! Whole-stack performance profile (EXPERIMENTS.md §Perf).
//!
//! Measures each hot path in isolation so regressions are attributable:
//!   * simulator fragment throughput (per-packet loop incl. loss draws);
//!   * TCP event-engine throughput;
//!   * Eq. 8 / Eq. 12 solver latency;
//!   * GF(256) slice kernel bandwidth (scalar vs SIMD dispatch);
//!   * wire-format encode/decode rate.

use janus::coordinator::packet::{encode_fragment_into, FragmentHeader, Packet};
use janus::erasure::gf256::MulTable;
use janus::metrics::bench::{time_it, BenchTable};
use janus::model::{
    optimize_deadline_paper, optimize_parity, LevelSchedule, NetParams,
};
use janus::sim::{run_guaranteed_error, run_tcp, BernoulliLoss, ParityPolicy, StaticLoss};

fn main() {
    let mut table = BenchTable::new("perf_profile", vec!["path", "metric", "value"]);
    table.header();

    // --- Simulator fragment loop ---
    let params = NetParams::paper_default(383.0);
    let sched = LevelSchedule::paper_nyx_scaled(4); // ~1.8 M fragments
    let frags_est = (sched.total_bytes(4).div_ceil(4096)) as f64 * 32.0 / 28.0;
    let (res, secs) = time_it(|| {
        let mut loss = StaticLoss::with_ttl(383.0, 1, 1.0 / params.r);
        run_guaranteed_error(&mut loss, &params, &sched, 4, &ParityPolicy::Static(4))
    });
    table.row(
        "sim fragment loop",
        vec![
            "Mfrag/s".into(),
            format!("{:.1}", res.fragments_sent as f64 / secs / 1e6),
        ],
    );
    let _ = frags_est;

    // --- TCP event engine ---
    let (tcp, secs) = time_it(|| {
        let mut loss = BernoulliLoss::new(0.02, 2);
        run_tcp(&mut loss, &params, 512 * 1024 * 1024)
    });
    table.row(
        "tcp event engine",
        vec![
            "Mpkt/s".into(),
            format!("{:.2}", tcp.packets_sent as f64 / secs / 1e6),
        ],
    );

    // --- Solvers ---
    let bytes = LevelSchedule::paper_nyx().total_bytes(4);
    let (_, secs) = time_it(|| {
        for _ in 0..20 {
            std::hint::black_box(optimize_parity(&params, bytes));
        }
    });
    table.row("Eq.8 solve", vec!["ms".into(), format!("{:.2}", secs / 20.0 * 1e3)]);
    let full = LevelSchedule::paper_nyx();
    let (_, secs) = time_it(|| {
        for _ in 0..5 {
            std::hint::black_box(optimize_deadline_paper(&params, &full, 401.11));
        }
    });
    table.row("Eq.12 exhaustive solve", vec!["ms".into(), format!("{:.2}", secs / 5.0 * 1e3)]);

    // --- GF(256) slice kernel ---
    let t = MulTable::new(0xC7);
    let x = vec![0x5Au8; 4096];
    let mut y = vec![0u8; 4096];
    let reps = 200_000;
    let (_, secs) = time_it(|| {
        for _ in 0..reps {
            t.mul_slice_add(&x, &mut y);
            std::hint::black_box(&y);
        }
    });
    table.row(
        "gf256 mul_slice_add",
        vec![
            "GB/s".into(),
            format!("{:.2}", reps as f64 * 4096.0 / secs / 1e9),
        ],
    );

    // --- Wire format ---
    let hdr = FragmentHeader { level: 1, stream: 0, ftg: 9, index: 3, k: 28, m: 4, seq: 77, pass: 0 };
    let payload = vec![0xABu8; 4096];
    let mut out = Vec::with_capacity(4200);
    let reps = 300_000;
    let (_, secs) = time_it(|| {
        for _ in 0..reps {
            encode_fragment_into(&hdr, &payload, &mut out);
            std::hint::black_box(&out);
        }
    });
    table.row(
        "fragment encode",
        vec!["Mfrag/s".into(), format!("{:.2}", reps as f64 / secs / 1e6)],
    );
    let encoded = out.clone();
    let (_, secs) = time_it(|| {
        for _ in 0..reps {
            std::hint::black_box(Packet::decode(&encoded).unwrap());
        }
    });
    table.row(
        "fragment decode",
        vec!["Mfrag/s".into(), format!("{:.2}", reps as f64 / secs / 1e6)],
    );

    table.save().unwrap();
    println!("\nperf_profile complete.");
}
