//! `janus serve` scaling matrix: one Virtual-mode daemon loop driving
//! 1, 64, and 1024 concurrent mem-transport transfers over a single
//! shared socket pair (transfer-id demux). Measures wall time,
//! completed transfers/s, and routed fragment datagrams/s per fan-out,
//! byte-exactness gated throughout. Emits
//! `target/bench-results/BENCH_serve.json` (uploaded by CI).

use janus::api::{AdaptConfig, Contract};
use janus::coordinator::{ReceiverConfig, SenderConfig};
use janus::metrics::bench::{bench_scale, BenchTable};
use janus::model::NetParams;
use janus::serve::{AdmissionPolicy, Daemon, ServeConfig, TimeMode, TransferOutcome};
use janus::transport::channel::mem_pair;
use janus::util::Pcg64;
use std::io::Write;
use std::path::PathBuf;
use std::time::{Duration, Instant};

const FANOUTS: [u32; 3] = [1, 64, 1024];
const RATE: f64 = 200_000.0;

fn payload(id: u32, n: usize) -> Vec<u8> {
    let mut rng = Pcg64::seeded(0x5E12 ^ u64::from(id));
    let mut v = vec![0u8; n];
    rng.fill_bytes(&mut v);
    v
}

fn sender_cfg() -> SenderConfig {
    SenderConfig {
        net: NetParams { t: 0.0005, r: RATE, lambda: 0.0, n: 32, s: 1024 },
        contract: Contract::Fidelity(1e-7),
        initial_lambda: 0.0,
        max_duration: Duration::from_secs(600),
        plane_cuts: vec![],
        adapt: AdaptConfig::fixed(),
    }
}

fn recv_cfg() -> ReceiverConfig {
    ReceiverConfig {
        t_w: 3.0,
        idle_timeout: Duration::from_secs(60),
        max_duration: Duration::from_secs(600),
    }
}

struct Outcome {
    concurrency: u32,
    wall_s: f64,
    fragments: u64,
    transfers_per_s: f64,
    datagrams_per_s: f64,
}

fn run_fanout(n: u32, size: usize) -> Outcome {
    let mut d = Daemon::new(ServeConfig { mode: TimeMode::Virtual, ..ServeConfig::default() });
    let (a, b) = mem_pair();
    let tx = d.add_socket(Box::new(a));
    let rx = d.add_socket(Box::new(b));
    let tenant = d.add_tenant("bench", u64::MAX, AdmissionPolicy::Queue);
    for id in 0..n {
        d.register_receiver(tenant, rx, id, recv_cfg(), size as u64).unwrap();
        d.register_sender(tenant, tx, id, sender_cfg(), vec![payload(id, size)], vec![1e-7])
            .unwrap();
    }
    let t0 = Instant::now();
    d.run_to_completion().expect("serve bench run");
    let wall_s = t0.elapsed().as_secs_f64().max(1e-9);

    let finished = d.take_finished();
    assert_eq!(finished.len(), 2 * n as usize, "fan-out {n}: every transfer must finish");
    let mut fragments = 0u64;
    let mut received = 0u32;
    for f in &finished {
        assert!(f.outcome.is_ok(), "fan-out {n} transfer {}: {:?}", f.id, f.outcome);
        match &f.outcome {
            TransferOutcome::Sent(rep) => fragments += rep.fragments_sent,
            TransferOutcome::Received(rep) => {
                assert_eq!(
                    rep.levels[0].as_deref(),
                    Some(&payload(f.id, size)[..]),
                    "fan-out {n} transfer {} bytes differ",
                    f.id
                );
                received += 1;
            }
            TransferOutcome::Failed(_) => unreachable!(),
        }
    }
    assert_eq!(received, n, "fan-out {n}: every receiver must complete");
    Outcome {
        concurrency: n,
        wall_s,
        fragments,
        transfers_per_s: f64::from(n) / wall_s,
        datagrams_per_s: fragments as f64 / wall_s,
    }
}

fn main() {
    // Default ≈ 25 KiB per transfer (~26 MB at the 1024 fan-out);
    // JANUS_SCALE=1 runs 256 KiB per transfer.
    let scale = bench_scale(10);
    let size = (256 * 1024 / scale as usize).max(1024);

    let outcomes: Vec<Outcome> = FANOUTS.iter().map(|&n| run_fanout(n, size)).collect();

    let mut table = BenchTable::new(
        "serve",
        vec!["concurrency", "wall_s", "transfers_per_s", "fragments", "kdatagrams_per_s"],
    );
    table.header();
    for o in &outcomes {
        table.row(
            format!("{}", o.concurrency),
            vec![
                format!("{:.3}", o.wall_s),
                format!("{:.1}", o.transfers_per_s),
                format!("{}", o.fragments),
                format!("{:.1}", o.datagrams_per_s / 1e3),
            ],
        );
    }
    table.save().unwrap();
    write_json(size, &outcomes).expect("write BENCH_serve.json");

    // The daemon must not collapse under fan-out: routing 1024 transfers
    // through one loop should still move fragments at a useful clip
    // relative to the single-transfer baseline.
    let single = &outcomes[0];
    let widest = &outcomes[outcomes.len() - 1];
    assert!(
        widest.datagrams_per_s > 0.05 * single.datagrams_per_s,
        "fan-out collapse: {:.0} dgram/s at {} transfers vs {:.0} at 1",
        widest.datagrams_per_s,
        widest.concurrency,
        single.datagrams_per_s
    );
    println!(
        "\nserve: 1×{:.0} dgram/s, {}×{:.0} dgram/s ({:.1} transfers/s at the widest fan-out)",
        single.datagrams_per_s, widest.concurrency, widest.datagrams_per_s,
        widest.transfers_per_s
    );
    println!("serve_throughput complete.");
}

/// Save the fan-out matrix as JSON (CI uploads this artifact as
/// `BENCH_serve`).
fn write_json(size: usize, outcomes: &[Outcome]) -> std::io::Result<PathBuf> {
    let dir = PathBuf::from("target/bench-results");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join("BENCH_serve.json");
    let mut f = std::fs::File::create(&path)?;
    writeln!(f, "{{")?;
    writeln!(f, "  \"bench\": \"serve\",")?;
    writeln!(f, "  \"transfer_bytes\": {size},")?;
    writeln!(f, "  \"nominal_rate\": {RATE},")?;
    writeln!(f, "  \"fanouts\": [")?;
    for (i, o) in outcomes.iter().enumerate() {
        writeln!(f, "    {{")?;
        writeln!(f, "      \"concurrency\": {},", o.concurrency)?;
        writeln!(f, "      \"wall_s\": {:.4},", o.wall_s)?;
        writeln!(f, "      \"transfers_per_s\": {:.2},", o.transfers_per_s)?;
        writeln!(f, "      \"fragments\": {},", o.fragments)?;
        writeln!(f, "      \"datagrams_per_s\": {:.1}", o.datagrams_per_s)?;
        writeln!(f, "    }}{}", if i + 1 < outcomes.len() { "," } else { "" })?;
    }
    writeln!(f, "  ]")?;
    writeln!(f, "}}")?;
    println!("[saved {}]", path.display());
    Ok(path)
}
