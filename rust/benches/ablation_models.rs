//! Ablations on the optimization machinery (beyond the paper's figures;
//! DESIGN.md §5 "Ablations").
//!
//! 1. Eq. 6 vs Eq. 7: the p-model crossover at λ·n/r = 1 — how far the
//!    two estimates diverge across λ, justifying the regime dispatch.
//! 2. Eq. 12 solver: exhaustive vs coordinate descent — solution quality
//!    and wall time (the scaling story for L > 4).
//! 3. T_W sensitivity: adaptive Alg. 1 total time vs measurement window
//!    under HMM loss (the paper fixes T_W = 3 s; this shows the tradeoff).

use janus::metrics::bench::{bench_scale, time_it, BenchTable};
use janus::model::error_model::{
    optimize_deadline_coordinate, optimize_deadline_exhaustive,
};
use janus::model::prob::{p_unrecoverable_high, p_unrecoverable_low};
use janus::model::{LevelSchedule, NetParams};
use janus::sim::estimator::{tracking_rmse, EwmaEstimator, LambdaEstimator, WindowEstimator};
use janus::sim::{run_guaranteed_error, HmmLoss, ParityPolicy};
use janus::util::stats;

fn main() {
    // --- 1. Eq. 6 vs Eq. 7 across λ ---
    let mut t1 = BenchTable::new(
        "ablation_p_models",
        vec!["lambda", "mean_losses_per_ftg", "p_eq6_m4", "p_eq7_m4", "ratio"],
    );
    t1.header();
    for lambda in [10.0, 19.0, 100.0, 383.0, 598.0, 700.0, 957.0, 2000.0] {
        let p = NetParams::paper_default(lambda);
        let mu = lambda * p.n as f64 / p.r;
        let p6 = p_unrecoverable_low(&p, 4);
        let p7 = p_unrecoverable_high(&p, 4);
        t1.row(
            format!("λ={lambda}"),
            vec![
                format!("{mu:.3}"),
                format!("{p6:.3e}"),
                format!("{p7:.3e}"),
                format!("{:.2}", p7 / p6.max(1e-300)),
            ],
        );
    }
    t1.save().unwrap();

    // --- 2. Solver comparison ---
    let sched = LevelSchedule::paper_nyx();
    let mut t2 = BenchTable::new(
        "ablation_solvers",
        vec!["case", "exhaustive_err", "cd_err", "exh_ms", "cd_ms", "same_plan"],
    );
    t2.header();
    for (lambda, tau) in [(19.0, 378.03), (383.0, 401.11), (957.0, 429.75)] {
        let p = NetParams::paper_default(lambda);
        let (ex, ex_s) = time_it(|| optimize_deadline_exhaustive(&p, &sched, tau).unwrap());
        let (cd, cd_s) = time_it(|| optimize_deadline_coordinate(&p, &sched, tau, 3).unwrap());
        t2.row(
            format!("λ={lambda} τ={tau}"),
            vec![
                format!("{:.3e}", ex.expected_error),
                format!("{:.3e}", cd.expected_error),
                format!("{:.1}", ex_s * 1e3),
                format!("{:.1}", cd_s * 1e3),
                format!("{}", ex.m == cd.m),
            ],
        );
        assert!(
            cd.expected_error <= ex.expected_error * 1.05 + 1e-12,
            "coordinate descent degraded > 5%"
        );
    }
    t2.save().unwrap();

    // --- 3. T_W sensitivity under HMM loss ---
    let scale = bench_scale(10);
    let sched_s = LevelSchedule::paper_nyx_scaled(scale);
    let params = NetParams::paper_default(383.0);
    let ttl = 1.0 / params.r;
    let mut t3 = BenchTable::new(
        "ablation_tw",
        vec!["T_W_s", "total_time_s", "m_changes"],
    );
    t3.header();
    let base_tw = if scale <= 1 { 3.0 } else { 3.0 / scale as f64 };
    for factor in [0.25, 0.5, 1.0, 2.0, 8.0] {
        let t_w = base_tw * factor;
        let mut times = Vec::new();
        let mut changes = Vec::new();
        for seed in 0..3 {
            let mut loss = HmmLoss::paper_default_with_ttl(500 + seed, ttl);
            let res = run_guaranteed_error(
                &mut loss,
                &params,
                &sched_s,
                4,
                &ParityPolicy::Adaptive { t_w, initial_lambda: 383.0 },
            );
            times.push(res.total_time);
            changes.push(res.m_changes.len() as f64);
        }
        t3.row(
            format!("{t_w:.3}"),
            vec![BenchTable::cell(&times), format!("{:.1}", stats::mean(&changes))],
        );
    }
    t3.save().unwrap();

    // --- 4. λ estimator comparison on the HMM trace ---
    let mut t4 = BenchTable::new("ablation_estimators", vec!["estimator", "rmse_losses_per_s"]);
    t4.header();
    let mut estimators: Vec<Box<dyn LambdaEstimator>> = vec![
        Box::new(WindowEstimator::new(3.0)),
        Box::new(WindowEstimator::new(1.0)),
        Box::new(EwmaEstimator::new(1.0, 0.3)),
        Box::new(EwmaEstimator::new(0.5, 0.2)),
    ];
    let labels = ["window T_W=3", "window T_W=1", "ewma 1s α=0.3", "ewma 0.5s α=0.2"];
    for (est, label) in estimators.iter_mut().zip(labels) {
        let mut loss = HmmLoss::paper_default_with_ttl(9, 1.0 / 19_144.0);
        let rmse = tracking_rmse(est.as_mut(), &mut loss, 19_144.0, 200.0);
        t4.row(label, vec![format!("{rmse:.1}")]);
    }
    t4.save().unwrap();
    println!("\nablation_models complete.");
}
