//! Table 2 — Error bounds of data received within a guaranteed
//! transmission time (real-network path).
//!
//! Five runs: Alg. 2 over real UDP sockets with a deadline set to 90% of
//! Alg. 1's measured duration for the same run conditions. Paper result:
//! 4 of 5 runs land at ε_2, one at ε_1 — i.e. the deadline is always met
//! at the cost of one or two tail levels.

use janus::api::{run_pair, ChannelTransport, Contract, Dataset, TransferSpec};
use janus::metrics::bench::{bench_scale, BenchTable};
use janus::model::{LevelSchedule, NetParams};
use janus::transport::{udp_pair, LossyChannel};
use janus::util::Pcg64;
use std::time::Duration;

fn main() -> janus::util::err::Result<()> {
    let scale = bench_scale(1000);
    let sched = LevelSchedule::paper_nyx_scaled(scale);
    let eps = sched.eps.clone();
    let mut rng = Pcg64::seeded(67);
    let levels: Vec<Vec<u8>> = sched
        .sizes
        .iter()
        .map(|&s| {
            let mut v = vec![0u8; s as usize];
            rng.fill_bytes(&mut v);
            v
        })
        .collect();
    let dataset = Dataset::new(levels.clone(), eps.clone())?;

    let rate = 30_000.0;
    let net = NetParams { t: 0.0005, r: rate, n: 32, s: 4096, lambda: 0.0 };
    let run_loss = [0.002, 0.008, 0.02, 0.035, 0.05];

    let mut table = BenchTable::new(
        "table2_deadline_realnet",
        vec!["run", "alg1_time_s", "constraint_s", "alg2_time_s", "achieved_eps"],
    );
    table.header();

    let spec_for = |contract: Contract, frac: f64| {
        TransferSpec::builder()
            .contract(contract)
            .net(net)
            .initial_lambda(frac * rate)
            .lambda_window(0.25)
            .idle_timeout(Duration::from_secs(15))
            .max_duration(Duration::from_secs(300))
            .build()
            .expect("table2 spec")
    };
    let mut met_deadline = 0;
    for (run, &frac) in run_loss.iter().enumerate() {
        // Alg. 1 first (its duration sets the deadline).
        let (tx, rx) = udp_pair()?;
        let sender_t = ChannelTransport::new(LossyChannel::new(tx, frac, 100 + run as u64));
        let spec1 = spec_for(Contract::Fidelity(eps[3]), frac);
        let rep1 = run_pair(&spec1, sender_t, ChannelTransport::new(rx), &dataset, None, None)?;
        let r1 = &rep1.received;
        let tau = 0.9 * r1.duration;

        // Alg. 2 at 90% of that time.
        let (tx2, rx2) = udp_pair()?;
        let sender_t2 = ChannelTransport::new(LossyChannel::new(tx2, frac, 200 + run as u64));
        let spec2 = spec_for(Contract::Deadline(tau), frac);
        let rep2 =
            run_pair(&spec2, sender_t2, ChannelTransport::new(rx2), &dataset, None, None)?;
        let r2 = &rep2.received;
        let eps_label = format!("eps_{}", r2.levels_recovered);
        if r2.duration <= tau * 1.25 {
            // 25% slack for wall-clock noise on loopback.
            met_deadline += 1;
        }
        table.row(
            format!("{} ({:.1}%)", run + 1, frac * 100.0),
            vec![
                format!("{:.2}", r1.duration),
                format!("{tau:.2}"),
                format!("{:.2}", r2.duration),
                eps_label,
            ],
        );
        // The prefix must be byte-exact.
        for i in 0..r2.levels_recovered {
            assert_eq!(r2.levels[i].as_ref().unwrap(), &levels[i], "run {run} level {i}");
        }
        assert!(
            r2.levels_recovered >= 1,
            "run {run}: at least level 1 must survive"
        );
    }
    table.save().unwrap();
    assert!(met_deadline >= 4, "deadline met only {met_deadline}/5 runs");
    println!("\ntable2 complete.");
    Ok(())
}
