//! Fig. 5 — Error bounds of data received within a guaranteed time under
//! time-varying (HMM) packet loss rates.
//!
//! τ = 388.8 s (the adaptive Alg. 1 time from Fig. 4). Static plans
//! solved for each fixed λ are compared against the adaptive Alg. 2 over
//! 100 runs. Paper claim: everyone meets τ (no retransmission), but the
//! adaptive plan achieves lower error bounds more often.

use janus::metrics::bench::{bench_runs, bench_scale, BenchTable};
use janus::model::{optimize_deadline_paper, LevelSchedule, NetParams};
use janus::sim::{run_guaranteed_time, DeadlinePolicy, HmmLoss};

fn main() {
    let scale = bench_scale(1); // survival probabilities need full-size N_j
    let runs = bench_runs(100);
    let sched = if scale <= 1 {
        LevelSchedule::paper_nyx()
    } else {
        LevelSchedule::paper_nyx_scaled(scale)
    };
    let tau = 388.8 / scale as f64;
    let params = NetParams::paper_default(383.0);
    let ttl = 1.0 / params.r;
    let t_w = if scale <= 1 { 3.0 } else { (3.0 / scale as f64).max(0.3) };

    let mut table = BenchTable::new(
        "fig5_hmm_deadline",
        vec!["config", "eps0", "eps1", "eps2", "eps3", "eps4", "overtime"],
    );
    table.header();

    // Static plans solved at each of the three HMM state means.
    let mut plans: Vec<(String, DeadlinePolicy)> = Vec::new();
    for lambda in [19.0, 383.0, 957.0] {
        let p = NetParams::paper_default(lambda);
        let opt = optimize_deadline_paper(&p, &sched, tau).expect("feasible");
        plans.push((
            format!("static λ={lambda} {:?}", opt.m),
            DeadlinePolicy::Static(opt.m),
        ));
    }
    plans.push((
        "adaptive (Alg.2)".to_string(),
        DeadlinePolicy::Adaptive { t_w, initial_lambda: 383.0 },
    ));

    let mut results: Vec<(String, [u32; 5], u32)> = Vec::new();
    for (label, policy) in &plans {
        let mut counts = [0u32; 5];
        let mut overtime = 0u32;
        for seed in 0..runs {
            let mut loss = HmmLoss::paper_default_with_ttl(7_700 + seed as u64, ttl);
            let res = run_guaranteed_time(&mut loss, &params, &sched, tau, policy).unwrap();
            counts[res.levels_recovered.min(4)] += 1;
            if res.total_time > tau * 1.02 {
                overtime += 1;
            }
        }
        table.row(
            label.clone(),
            (0..5)
                .map(|i| counts[i].to_string())
                .chain([format!("{overtime}/{runs}")])
                .collect(),
        );
        results.push((label.clone(), counts, overtime));
    }
    table.save().unwrap();

    // Shape checks: everyone meets τ; adaptive ≥ static in low-ε mass.
    for (label, _, overtime) in &results {
        assert_eq!(*overtime, 0, "{label} exceeded τ");
    }
    let low_eps_mass = |c: &[u32; 5]| c[3] + c[4]; // ≥3 levels (ε_3 or better)
    let adaptive_mass = low_eps_mass(&results.last().unwrap().1);
    let best_static_mass = results[..results.len() - 1]
        .iter()
        .map(|(_, c, _)| low_eps_mass(c))
        .max()
        .unwrap();
    println!(
        "\nadaptive ε≤ε_3 in {adaptive_mass}/{runs} runs; best static {best_static_mass}/{runs}"
    );
    assert!(
        adaptive_mass + 5 >= best_static_mass,
        "adaptive should be competitive with the best static plan"
    );
    println!("fig5 complete.");
}
