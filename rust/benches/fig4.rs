//! Fig. 4 — Total time for transferring data with guaranteed error bound
//! under time-varying (HMM) packet loss rates.
//!
//! Compares TCP, static UDP+EC (m = 0..16) and the adaptive protocol
//! (Alg. 1). Paper claim: the adaptive protocol beats the best static
//! configuration (by ~30 s on the full workload, 388.8 s total).

use janus::metrics::bench::{bench_runs, bench_scale, BenchTable};
use janus::model::{LevelSchedule, NetParams};
use janus::sim::{
    run_guaranteed_error, run_tcp, FractionOfRate, HmmLoss, ParityPolicy,
};
use janus::util::stats;

fn main() {
    let scale = bench_scale(10);
    let runs = bench_runs(5);
    let sched = if scale <= 1 {
        LevelSchedule::paper_nyx()
    } else {
        LevelSchedule::paper_nyx_scaled(scale)
    };
    let params = NetParams::paper_default(383.0); // λ field unused by HMM
    let ttl = 1.0 / params.r;
    let bytes = sched.total_bytes(4);

    // NOTE on T_W scaling: the HMM holds each state ~25 s regardless of
    // workload scale, so at scale > 1 the transfer spans fewer states.
    // We keep the paper's T_W = 3 s at scale 1 and shrink it with the
    // workload so adaptation still sees several windows per state.
    let t_w = if scale <= 1 { 3.0 } else { (3.0 / scale as f64).max(0.3) };

    let mut table = BenchTable::new(
        "fig4_hmm",
        vec!["config", "total_time_s", "rounds", "lost_frags"],
    );
    table.header();

    // TCP over the same HMM regime (per-packet fraction λ(t)/r).
    let tcp_times: Vec<f64> = (0..runs)
        .map(|seed| {
            let inner = HmmLoss::paper_default(seed as u64);
            let mut loss = FractionOfRate::new(inner, params.r, 50 + seed as u64);
            run_tcp(&mut loss, &params, bytes).total_time
        })
        .collect();
    table.row("TCP", vec![BenchTable::cell(&tcp_times), "-".into(), "-".into()]);

    let mut best_static = f64::INFINITY;
    for m in 0..=16usize {
        let mut times = Vec::new();
        let mut rounds = Vec::new();
        let mut lost = Vec::new();
        for seed in 0..runs {
            let mut loss = HmmLoss::paper_default_with_ttl(300 + seed as u64, ttl);
            let res =
                run_guaranteed_error(&mut loss, &params, &sched, 4, &ParityPolicy::Static(m));
            times.push(res.total_time);
            rounds.push(res.rounds as f64);
            lost.push(res.fragments_lost as f64);
        }
        best_static = best_static.min(stats::median(&times));
        table.row(
            format!("static m={m}"),
            vec![
                BenchTable::cell(&times),
                format!("{:.1}", stats::mean(&rounds)),
                format!("{:.0}", stats::mean(&lost)),
            ],
        );
    }

    let mut adap_times = Vec::new();
    let mut adap_rounds = Vec::new();
    let mut adap_lost = Vec::new();
    for seed in 0..runs {
        let mut loss = HmmLoss::paper_default_with_ttl(300 + seed as u64, ttl);
        let res = run_guaranteed_error(
            &mut loss,
            &params,
            &sched,
            4,
            &ParityPolicy::Adaptive { t_w, initial_lambda: 383.0 },
        );
        adap_times.push(res.total_time);
        adap_rounds.push(res.rounds as f64);
        adap_lost.push(res.fragments_lost as f64);
    }
    table.row(
        "adaptive (Alg.1)",
        vec![
            BenchTable::cell(&adap_times),
            format!("{:.1}", stats::mean(&adap_rounds)),
            format!("{:.0}", stats::mean(&adap_lost)),
        ],
    );
    table.save().unwrap();

    let adaptive = stats::median(&adap_times);
    println!(
        "\nadaptive {adaptive:.2}s vs best static {best_static:.2}s vs TCP {:.2}s",
        stats::median(&tcp_times)
    );
    assert!(
        adaptive <= best_static * 1.02,
        "adaptive ({adaptive:.2}) should match or beat best static ({best_static:.2})"
    );
    println!("fig4 complete.");
}
