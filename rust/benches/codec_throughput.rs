//! Progressive-codec throughput + ε gate (EXPERIMENTS.md §Codec).
//!
//! Measures the encode path (lifting + bitplane + planner + container)
//! and the progressive decode path at every rung prefix, then asserts
//! the codec's contract: every recorded rung ε meets its request, every
//! prefix's ground-truth error stays within the recorded bound, and the
//! container undercuts the raw f32 volume. Emits
//! `target/bench-results/BENCH_codec.json` (uploaded by CI as the
//! `BENCH_codec` artifact alongside `BENCH_datapath.json`).
//!
//! `JANUS_SCALE` ≥ 10 shrinks the volume for CI smoke runs.

use janus::codec::{encode, CodecConfig, Decoder};
use janus::metrics::bench::{bench_scale, time_it, BenchTable};
use janus::refactor::{generate, GrfConfig};
use std::io::Write;
use std::path::PathBuf;

fn write_codec_json(
    d: usize,
    rungs: usize,
    raw_bytes: u64,
    container_bytes: u64,
    encode_mb_s: f64,
    decode_mb_s: f64,
    eps: &[f64],
) -> std::io::Result<PathBuf> {
    let dir = PathBuf::from("target/bench-results");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join("BENCH_codec.json");
    let mut f = std::fs::File::create(&path)?;
    writeln!(f, "{{")?;
    writeln!(f, "  \"bench\": \"codec\",")?;
    writeln!(f, "  \"d\": {d},")?;
    writeln!(f, "  \"rungs\": {rungs},")?;
    writeln!(f, "  \"raw_bytes\": {raw_bytes},")?;
    writeln!(f, "  \"container_bytes\": {container_bytes},")?;
    writeln!(
        f,
        "  \"compression_ratio\": {:.4},",
        container_bytes as f64 / raw_bytes as f64
    )?;
    writeln!(f, "  \"encode_mb_per_s\": {encode_mb_s:.2},")?;
    writeln!(f, "  \"decode_mb_per_s\": {decode_mb_s:.2},")?;
    let eps_list: Vec<String> = eps.iter().map(|e| format!("{e:.6e}")).collect();
    writeln!(f, "  \"achieved_eps\": [{}]", eps_list.join(", "))?;
    writeln!(f, "}}")?;
    println!("[saved {}]", path.display());
    Ok(path)
}

fn main() {
    let mut table = BenchTable::new("codec_throughput", vec!["path", "metric", "value"]);
    table.header();

    // Scale-aware geometry: d = 64 full, 32 under CI smoke (`JANUS_SCALE`).
    let d = if bench_scale(1) >= 10 { 32 } else { 64 };
    let cfg = CodecConfig { levels: 4, ladder: vec![4e-3, 5e-4, 6e-5], max_planes: 24 };
    let vol = generate(d, &GrfConfig::default(), 7);
    let raw_bytes = (d * d * d * 4) as u64;

    // --- Encode (lifting + planes + planner + serialization) ---
    let runs = 3usize;
    let (enc, secs) = time_it(|| {
        let mut last = None;
        for _ in 0..runs {
            last = Some(encode(&vol, &cfg).expect("encode"));
        }
        last.expect("ran at least once")
    });
    let encode_mb_s = runs as f64 * raw_bytes as f64 / secs / 1e6;
    table.row(
        "codec encode",
        vec!["MB/s raw".into(), format!("{encode_mb_s:.1}")],
    );
    table.row(
        "container ratio",
        vec![
            "frac of raw".into(),
            format!("{:.3}", enc.total_bytes() as f64 / raw_bytes as f64),
        ],
    );

    // --- Progressive decode at every rung prefix ---
    let refs: Vec<&[u8]> = enc.rungs.iter().map(|r| r.as_slice()).collect();
    let mut decoded_bytes = 0u64;
    let (outs, secs) = time_it(|| {
        let mut outs = Vec::new();
        for used in 1..=refs.len() {
            outs.push(Decoder::decode(&refs[..used]).expect("decode prefix"));
        }
        outs
    });
    for used in 1..=refs.len() {
        decoded_bytes += refs[..used].iter().map(|r| r.len() as u64).sum::<u64>();
    }
    let decode_mb_s = decoded_bytes as f64 / secs / 1e6;
    table.row(
        "codec decode (all prefixes)",
        vec!["MB/s container".into(), format!("{decode_mb_s:.1}")],
    );

    // --- The codec's contract, asserted ---
    for (r, ((rec, req), out)) in enc.eps.iter().zip(&cfg.ladder).zip(&outs).enumerate() {
        assert!(rec <= req, "rung {r}: recorded ε {rec} exceeds requested {req}");
        let true_err = vol.linf_rel_error(&out.volume);
        assert!(
            true_err <= out.achieved_eps + 1e-12,
            "rung {r}: ground truth {true_err} exceeds reported {}",
            out.achieved_eps
        );
        assert!(
            (out.achieved_eps - rec).abs() < 1e-15,
            "rung {r}: decoder reports the recorded ε"
        );
        table.row(
            &format!("rung {} ε", r + 1),
            vec!["achieved".into(), format!("{:.3e}", out.achieved_eps)],
        );
    }
    assert!(
        enc.total_bytes() < raw_bytes,
        "container must undercut raw f32: {} vs {raw_bytes}",
        enc.total_bytes()
    );
    // Loose smoke floor: even a debug-adjacent CI runner encodes a small
    // volume faster than 1 MB/s; the JSON records the real number.
    assert!(encode_mb_s > 1.0, "encode collapsed: {encode_mb_s:.2} MB/s");

    write_codec_json(
        d,
        enc.rungs.len(),
        raw_bytes,
        enc.total_bytes(),
        encode_mb_s,
        decode_mb_s,
        &enc.eps,
    )
    .unwrap();
    table.save().unwrap();
    println!("\ncodec_throughput complete.");
}
