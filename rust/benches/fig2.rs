//! Fig. 2 — Total time for transferring data with guaranteed error bound
//! under static packet loss rates.
//!
//! Three panels (λ = 19 / 383 / 957 losses/s). Each panel: TCP baseline,
//! UDP+EC simulation for m = 0..16, and the model's E[T_total] (Eq. 2/8)
//! for the same m — the paper's claim is that model and simulation align
//! and that an interior optimal m appears as λ grows.
//!
//! `JANUS_SCALE=1 cargo bench --bench fig2` reproduces the full 26.75 GB
//! workload; the default scale (10) keeps the sweep under a minute and
//! scales all times by 1/10.

use janus::metrics::bench::{bench_runs, bench_scale, BenchTable};
use janus::model::{
    expected_time_curve, LevelSchedule, NetParams,
};
use janus::sim::{run_guaranteed_error, run_tcp, BernoulliLoss, ParityPolicy, StaticLoss};
use janus::util::stats;

fn main() {
    let scale = bench_scale(10);
    let runs = bench_runs(3);
    let sched = if scale <= 1 {
        LevelSchedule::paper_nyx()
    } else {
        LevelSchedule::paper_nyx_scaled(scale)
    };
    let bytes = sched.total_bytes(4);
    println!(
        "fig2: workload {} MB (scale 1/{scale}), {runs} seeds per point",
        bytes / (1024 * 1024)
    );

    for (panel, lambda) in [("a", 19.0), ("b", 383.0), ("c", 957.0)] {
        let params = NetParams::paper_default(lambda);
        let ttl = 1.0 / params.r;
        let mut table = BenchTable::new(
            &format!("fig2{panel}_lambda{}", lambda as u64),
            vec!["m", "sim_time_s", "model_time_s", "retrans_ftgs"],
        );
        table.header();

        // TCP baseline (loss as per-packet fraction λ/r, see DESIGN.md §3).
        let tcp_times: Vec<f64> = (0..runs)
            .map(|seed| {
                let mut loss = BernoulliLoss::new(lambda / params.r, 7_000 + seed as u64);
                run_tcp(&mut loss, &params, bytes).total_time
            })
            .collect();
        table.row("TCP", vec![BenchTable::cell(&tcp_times), "-".into(), "-".into()]);

        // Model curve for every m.
        let curve = expected_time_curve(&params, bytes, 16);

        for m in 0..=16usize {
            let mut times = Vec::new();
            let mut retrans = Vec::new();
            for seed in 0..runs {
                let mut loss =
                    StaticLoss::with_ttl(lambda, 100 * (m as u64 + 1) + seed as u64, ttl);
                let res =
                    run_guaranteed_error(&mut loss, &params, &sched, 4, &ParityPolicy::Static(m));
                times.push(res.total_time);
                retrans.push(res.ftgs_retransmitted as f64);
            }
            table.row(
                format!("UDP+EC m={m}"),
                vec![
                    BenchTable::cell(&times),
                    format!("{:.2}", curve[m].expected_time),
                    format!("{:.0}", stats::mean(&retrans)),
                ],
            );
        }
        table.save().unwrap();

        // Shape checks mirrored from the paper's observations.
        let sim_m = |m: usize| {
            let mut loss = StaticLoss::with_ttl(lambda, 4242 + m as u64, ttl);
            run_guaranteed_error(&mut loss, &params, &sched, 4, &ParityPolicy::Static(m)).total_time
        };
        if lambda < 100.0 {
            // (a): parity only adds overhead at low loss.
            assert!(sim_m(0) < sim_m(16), "fig2a shape: m=0 should beat m=16");
        } else {
            // (b)/(c): an interior m beats both endpoints.
            let best_interior = (2..=12).map(sim_m).fold(f64::INFINITY, f64::min);
            assert!(
                best_interior < sim_m(0),
                "fig2{panel} shape: interior m should beat m=0"
            );
        }
    }
    println!("\nfig2 complete.");
}
