//! Multi-stream transfer throughput vs the single-stream path, both
//! driven through the `janus::api` facade (acceptance gate: ≥ 2×
//! aggregate encode+transfer throughput on the same input with 4
//! streams).
//!
//! Both paths carry the same dataset over in-memory channels with the
//! same per-stream pacing rate; the pool's win comes from N concurrent
//! paced endpoints and N parallel Reed–Solomon encoders — exactly the
//! Petascale-DTN many-streams effect the tentpole reproduces. A second
//! table isolates the encode side via `measure_parallel_ec_rate`.

use janus::api::{mem_transport_pair, run_pair, Contract, Dataset, TransferSpec};
use janus::erasure::{measure_ec_rate, measure_parallel_ec_rate};
use janus::metrics::bench::{bench_runs, bench_scale, BenchTable};
use janus::model::NetParams;
use janus::testkit::{loss_transport_pair, LossTrace};
use janus::util::{stats, Pcg64};
use std::io::Write;
use std::path::PathBuf;
use std::time::{Duration, Instant};

fn dataset(total: usize) -> Dataset {
    let mut rng = Pcg64::seeded(0x9001);
    let sizes = [total / 10, total * 3 / 10, total * 6 / 10];
    let eps = vec![0.004, 0.0005, 0.0000001];
    Dataset::new(
        sizes
            .iter()
            .map(|&sz| {
                let mut v = vec![0u8; sz.max(1)];
                rng.fill_bytes(&mut v);
                v
            })
            .collect(),
        eps,
    )
    .expect("bench dataset")
}

fn main() {
    // Default ≈ 12 MB; JANUS_SCALE=1 runs ~120 MB.
    let scale = bench_scale(10);
    let runs = bench_runs(3);
    let total = 120 * 1024 * 1024 / scale as usize;
    let dataset = dataset(total);
    let bytes = dataset.total_bytes() as usize;
    let per_stream_rate = 100_000.0; // fragments/s, 4 KiB each
    let net = NetParams { t: 0.0005, r: per_stream_rate, lambda: 0.0, n: 32, s: 4096 };
    println!(
        "pool_throughput: {:.1} MB dataset, per-stream rate {per_stream_rate:.0} frag/s, {runs} runs",
        bytes as f64 / 1e6
    );

    let spec_at = |streams: usize| {
        TransferSpec::builder()
            .contract(Contract::Fidelity(1e-7))
            .streams(streams)
            .net(net)
            .lambda_window(0.25)
            .idle_timeout(Duration::from_secs(30))
            .max_duration(Duration::from_secs(600))
            .build()
            .expect("bench spec")
    };
    let mbps_at = |streams: usize| -> Vec<f64> {
        let spec = spec_at(streams);
        let mut out = Vec::new();
        for _ in 0..runs {
            let (sender_t, receiver_t) = mem_transport_pair(streams);
            let t0 = Instant::now();
            let rep = run_pair(&spec, sender_t, receiver_t, &dataset, None, None).unwrap();
            let wall = t0.elapsed().as_secs_f64();
            assert_eq!(rep.received.levels_recovered, 3, "must deliver");
            assert_eq!(rep.sent.passes, 0);
            out.push(bytes as f64 / 1e6 / wall);
        }
        out
    };

    let mut table = BenchTable::new(
        "pool_throughput",
        vec!["path", "MB_per_s", "wall_s", "passes"],
    );
    table.header();

    // --- Single-stream baseline: the facade's streams = 1 route. ---
    let single_mbps = mbps_at(1);
    table.row(
        "single-stream session",
        vec![BenchTable::cell(&single_mbps), "-".into(), "0".into()],
    );

    // --- Pool at 2, 4, 8 streams (the facade's pooled route). ---
    let mut by_streams = vec![(1usize, stats::median(&single_mbps))];
    for streams in [2usize, 4, 8] {
        let mbps = mbps_at(streams);
        table.row(
            format!("pool {streams} streams"),
            vec![BenchTable::cell(&mbps), "-".into(), "0".into()],
        );
        by_streams.push((streams, stats::median(&mbps)));
    }
    table.save().unwrap();

    // --- Encode-side isolation: parallel worker-pool RS throughput. ---
    let mut enc = BenchTable::new(
        "pool_encode_scaling",
        vec!["workers", "frag_per_s", "speedup"],
    );
    enc.header();
    let base = measure_ec_rate(32, 8, 4096, 0.3, 1).fragments_per_sec;
    enc.row("1", vec![format!("{base:.0}"), "1.00x".into()]);
    for workers in [2usize, 4, 8] {
        let r = measure_parallel_ec_rate(32, 8, 4096, 0.3, 1, workers).fragments_per_sec;
        enc.row(
            format!("{workers}"),
            vec![format!("{r:.0}"), format!("{:.2}x", r / base)],
        );
    }
    enc.save().unwrap();

    // --- Pooled Deadline: pass-barrier τ accounting on 4 streams over
    // a 5%-loss deterministic testkit wire (tentpole gate: τ met in
    // virtual time with retransmission absorbed by the budget, receiver
    // ε equal to the advertisement). Emits BENCH_pool_deadline.json,
    // uploaded by the CI bench-smoke step. ---
    let dl_streams = 4usize;
    let dl_loss = 0.05;
    let tau = 600.0; // generous virtual budget: nothing should be shed
    let dl_spec = TransferSpec::builder()
        .contract(Contract::Deadline(tau))
        .streams(dl_streams)
        .net(net)
        .initial_lambda(dl_loss * per_stream_rate * dl_streams as f64)
        .lambda_window(0.25)
        .idle_timeout(Duration::from_secs(30))
        .max_duration(Duration::from_secs(600))
        .build()
        .expect("pooled deadline spec");
    let (st, rt) =
        loss_transport_pair(dl_streams, |w| LossTrace::seeded(dl_loss, 0xD1 + w as u64));
    let t0 = Instant::now();
    let rep = run_pair(&dl_spec, st, rt, &dataset, None, None).expect("pooled deadline run");
    let wall = t0.elapsed().as_secs_f64();
    let dl = rep.sent.deadline().expect("deadline outcome").clone();
    let dl_mbps = bytes as f64 / 1e6 / wall;
    println!(
        "\npool-deadline 4 streams @ {:.0}% loss: {dl_mbps:.1} MB/s, virtual {:.4}s / τ {tau}s ({}), \
         advertised ε ≤ {:.1e}, receiver ε ≤ {:.1e}",
        dl_loss * 100.0,
        dl.virtual_elapsed,
        if dl.met { "met" } else { "MISSED" },
        dl.advertised_eps,
        rep.received.achieved_eps,
    );
    write_deadline_json(dl_streams, dl_loss, &dl, dl_mbps, rep.received.achieved_eps)
        .expect("write BENCH_pool_deadline.json");
    assert!(dl.met, "generous τ must be met in virtual time: {dl:?}");
    assert!(
        (rep.received.achieved_eps - dl.advertised_eps).abs() < 1e-15,
        "receiver must certify the advertisement"
    );
    assert_eq!(rep.received.levels_recovered, 3, "nothing shed under a generous τ");

    // --- Acceptance gates ---
    let single = stats::median(&single_mbps);
    let four = by_streams.iter().find(|&&(s, _)| s == 4).unwrap().1;
    println!(
        "\nsingle-stream {single:.1} MB/s vs pool×4 {four:.1} MB/s ({:.2}x)",
        four / single
    );
    assert!(
        four >= 2.0 * single,
        "pool×4 ({four:.1} MB/s) must be ≥ 2× single-stream ({single:.1} MB/s)"
    );
    println!("pool_throughput complete.");
}

/// Save the pooled-deadline gate numbers as JSON (CI uploads this
/// artifact as `BENCH_pool_deadline`).
fn write_deadline_json(
    streams: usize,
    loss: f64,
    dl: &janus::api::DeadlineOutcome,
    mbps: f64,
    receiver_eps: f64,
) -> std::io::Result<PathBuf> {
    let dir = PathBuf::from("target/bench-results");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join("BENCH_pool_deadline.json");
    let mut f = std::fs::File::create(&path)?;
    writeln!(f, "{{")?;
    writeln!(f, "  \"bench\": \"pool_deadline\",")?;
    writeln!(f, "  \"streams\": {streams},")?;
    writeln!(f, "  \"loss\": {loss},")?;
    writeln!(f, "  \"tau_s\": {},", dl.tau)?;
    writeln!(f, "  \"virtual_elapsed_s\": {:.6},", dl.virtual_elapsed)?;
    writeln!(f, "  \"met\": {},", dl.met)?;
    writeln!(f, "  \"planned_eps\": {:e},", dl.planned_eps)?;
    writeln!(f, "  \"advertised_eps\": {:e},", dl.advertised_eps)?;
    writeln!(f, "  \"receiver_eps\": {receiver_eps:e},")?;
    writeln!(f, "  \"mb_per_s\": {mbps:.2}")?;
    writeln!(f, "}}")?;
    println!("[saved {}]", path.display());
    Ok(path)
}
