//! Fig. 6 — Total time for transferring data with guaranteed error bound
//! using different data transfer protocols over the (substituted) real
//! network.
//!
//! The paper's five test runs on a workstation→CloudLab path become five
//! loopback runs with different injected loss fractions (the WAN
//! substitute, DESIGN.md §3): native TCP and Globus are simulated at the
//! measured loss fraction; Janus Alg. 1 actually runs over UDP sockets
//! with the real coordinator engines.
//!
//! Paper claim: TCP/Globus vary wildly across runs; Janus is faster and
//! far more stable.

use janus::api::{run_pair, ChannelTransport, Contract, Dataset, TransferSpec};
use janus::metrics::bench::{bench_scale, BenchTable};
use janus::model::{LevelSchedule, NetParams};
use janus::sim::globus::{run_globus, GlobusConfig};
use janus::sim::{run_tcp, BernoulliLoss};
use janus::transport::{udp_pair, LossyChannel};
use janus::util::{stats, Pcg64};
use std::time::Duration;

fn main() -> janus::util::err::Result<()> {
    // Real-socket workload: scaled-down level schedule carried as bytes.
    let scale = bench_scale(1000); // 26.75 GB / 1000 ≈ 27 MB on loopback
    let sched = LevelSchedule::paper_nyx_scaled(scale);
    let eps = sched.eps.clone();
    let mut rng = Pcg64::seeded(66);
    let levels: Vec<Vec<u8>> = sched
        .sizes
        .iter()
        .map(|&s| {
            let mut v = vec![0u8; s as usize];
            rng.fill_bytes(&mut v);
            v
        })
        .collect();
    let total: u64 = sched.sizes.iter().sum();
    let dataset = Dataset::new(levels.clone(), eps.clone())?;

    // Loopback pacing: fast enough to finish quickly, slow enough that
    // the kernel never drops for us (we inject losses ourselves).
    let rate = 30_000.0;
    let net = NetParams { t: 0.0005, r: rate, n: 32, s: 4096, lambda: 0.0 };
    // The WAN loss fraction drawn per "day" (per run), like the paper's
    // five runs on different days.
    let run_loss = [0.002, 0.008, 0.02, 0.035, 0.05];

    let mut table = BenchTable::new(
        "fig6_realnet",
        vec!["run", "tcp_s", "globus_s", "janus_s", "janus_passes"],
    );
    table.header();

    let mut tcp_all = Vec::new();
    let mut glb_all = Vec::new();
    let mut janus_all = Vec::new();
    for (run, &frac) in run_loss.iter().enumerate() {
        // Baselines simulated at the same fraction & rate but at the
        // paper's measured WAN latency (t = 10 ms): the loopback only
        // substitutes the wire, not the WAN RTT that TCP is sensitive to.
        let wan = NetParams { t: 0.01, ..net };
        let mut tcp_loss = BernoulliLoss::new(frac, 80 + run as u64);
        let tcp = run_tcp(&mut tcp_loss, &wan, total).total_time;
        let globus = run_globus(
            &GlobusConfig { startup: 2.0, ..GlobusConfig::default() },
            &wan,
            total,
            frac,
            90 + run as u64,
        )
        .total_time;

        // Janus over real UDP sockets, driven through the api facade.
        let (tx, rx) = udp_pair()?;
        let sender_t = ChannelTransport::new(LossyChannel::new(tx, frac, 7_000 + run as u64));
        let receiver_t = ChannelTransport::new(rx);
        let spec = TransferSpec::builder()
            .contract(Contract::Fidelity(eps[3]))
            .net(net)
            .initial_lambda(frac * rate)
            .lambda_window(0.25)
            .idle_timeout(Duration::from_secs(15))
            .max_duration(Duration::from_secs(300))
            .build()
            .expect("fig6 spec");
        let rep = run_pair(&spec, sender_t, receiver_t, &dataset, None, None)?;
        let (s_rep, r_rep) = (&rep.sent, &rep.received);
        assert_eq!(r_rep.levels_recovered, 4, "run {run}: Janus must deliver all levels");
        for (got, want) in r_rep.levels.iter().zip(&levels) {
            assert_eq!(got.as_ref().unwrap(), want, "run {run}: bytes must be exact");
        }

        table.row(
            format!("run{} ({:.1}%)", run + 1, frac * 100.0),
            vec![
                format!("{tcp:.2}"),
                format!("{globus:.2}"),
                format!("{:.2}", r_rep.duration),
                format!("{}", s_rep.passes),
            ],
        );
        tcp_all.push(tcp);
        glb_all.push(globus);
        janus_all.push(r_rep.duration);
    }
    table.row(
        "median",
        vec![
            format!("{:.2}", stats::median(&tcp_all)),
            format!("{:.2}", stats::median(&glb_all)),
            format!("{:.2}", stats::median(&janus_all)),
            "-".into(),
        ],
    );
    table.row(
        "spread (max−min)",
        vec![
            format!("{:.2}", stats::min_max(&tcp_all).1 - stats::min_max(&tcp_all).0),
            format!("{:.2}", stats::min_max(&glb_all).1 - stats::min_max(&glb_all).0),
            format!("{:.2}", stats::min_max(&janus_all).1 - stats::min_max(&janus_all).0),
            "-".into(),
        ],
    );
    table.save().unwrap();

    // Shape checks (paper Fig. 6): Janus faster than both baselines on
    // every run and far more stable than TCP across runs.
    for i in 0..janus_all.len() {
        assert!(
            janus_all[i] < tcp_all[i] && janus_all[i] < glb_all[i],
            "run {i}: janus {:.2} not fastest (tcp {:.2}, globus {:.2})",
            janus_all[i],
            tcp_all[i],
            glb_all[i]
        );
    }
    let spread = |xs: &[f64]| stats::min_max(xs).1 - stats::min_max(xs).0;
    assert!(spread(&janus_all) < spread(&tcp_all));
    println!("\nfig6 complete.");
    Ok(())
}
