//! Adaptive pacing matrix: the same pooled transfer under (a) uniform
//! 20% loss, (b) Gilbert-Elliott 20% mean loss in 8-fragment bursts at
//! the same mean λ, (c) the GE channel with the burst-aware solver
//! disabled (i.i.d. baseline), and (d) a rate-responsive congestion
//! policer at half the nominal rate. Emits the scenario numbers —
//! passes, fragments, wall time, full per-barrier rate trajectory — as
//! `target/bench-results/BENCH_pacing.json` (uploaded by CI).

use janus::api::{
    run_pair, AdaptConfig, Contract, Dataset, FnObserver, TransferEvent, TransferReport,
    TransferSpec,
};
use janus::metrics::bench::{bench_scale, BenchTable};
use janus::model::NetParams;
use janus::testkit::{congestion_transport_pair, loss_transport_pair, LossTrace};
use janus::util::Pcg64;
use std::io::Write;
use std::path::PathBuf;
use std::time::{Duration, Instant};

const STREAMS: usize = 4;
const RATE: f64 = 200_000.0;
const LOSS: f64 = 0.2;
const BURST: f64 = 8.0;

fn dataset(total: usize) -> Dataset {
    let mut rng = Pcg64::seeded(0xACE5);
    let sizes = [total / 10, total * 3 / 10, total * 6 / 10];
    let eps = vec![0.004, 0.0005, 0.0000001];
    Dataset::new(
        sizes
            .iter()
            .map(|&sz| {
                let mut v = vec![0u8; sz.max(1)];
                rng.fill_bytes(&mut v);
                v
            })
            .collect(),
        eps,
    )
    .expect("bench dataset")
}

fn spec(initial_lambda: f64, adapt: AdaptConfig) -> TransferSpec {
    TransferSpec::builder()
        .contract(Contract::Fidelity(1e-7))
        .streams(STREAMS)
        .net(NetParams { t: 0.0005, r: RATE, lambda: 0.0, n: 32, s: 1024 })
        .initial_lambda(initial_lambda)
        .lambda_window(0.25)
        .idle_timeout(Duration::from_secs(30))
        .max_duration(Duration::from_secs(600))
        .adaptation(adapt)
        .build()
        .expect("bench spec")
}

struct Outcome {
    name: &'static str,
    passes: u32,
    fragments: u64,
    wall_s: f64,
    min_rate: f64,
    max_m: usize,
    rates: Vec<f64>,
}

fn outcome(name: &'static str, rep: &TransferReport, wall_s: f64, data: &Dataset) -> Outcome {
    assert_eq!(
        rep.received.levels_recovered,
        data.levels.len(),
        "{name}: must deliver the full ladder"
    );
    let rates = rep.sent.rate_history.clone();
    Outcome {
        name,
        passes: rep.sent.passes,
        fragments: rep.sent.fragments_sent,
        wall_s,
        min_rate: rates.iter().cloned().fold(RATE, f64::min),
        max_m: rep.sent.trace().map(|t| t.iter().map(|p| p.m).max().unwrap_or(0)).unwrap_or(0),
        rates,
    }
}

fn main() {
    // Default ≈ 2.4 MB of payload; JANUS_SCALE=1 runs ~24 MB.
    let scale = bench_scale(10);
    let data = dataset(24 * 1024 * 1024 / scale as usize);
    let lambda0 = LOSS * RATE * STREAMS as f64;

    let run_lossy = |name, trace: fn(u64) -> LossTrace, adapt| {
        let (st, rt) = loss_transport_pair(STREAMS, |w| trace(0xBEEF ^ (w as u64 + 1) * 0x9E37));
        let t0 = Instant::now();
        let rep = run_pair(&spec(lambda0, adapt), st, rt, &data, None, None).expect(name);
        outcome(name, &rep, t0.elapsed().as_secs_f64(), &data)
    };

    let uniform = run_lossy("uniform", |s| LossTrace::seeded(LOSS, s), AdaptConfig::default());
    let ge = run_lossy(
        "ge_burst",
        |s| LossTrace::gilbert_elliott(LOSS, BURST, RATE, s),
        AdaptConfig::default(),
    );
    let ge_iid = run_lossy(
        "ge_burst_iid_solver",
        |s| LossTrace::gilbert_elliott(LOSS, BURST, RATE, s),
        AdaptConfig::fixed(),
    );

    // Congestion: the observer closes the loop, feeding each RateAdapted
    // barrier decision back into the policer's token bucket.
    let congestion = {
        let (st, rt, handle) = congestion_transport_pair(STREAMS, 0.5 * RATE, RATE);
        let h = handle.clone();
        let mut obs = FnObserver(move |e: &TransferEvent| {
            if let TransferEvent::RateAdapted { rate, .. } = e {
                h.set(*rate);
            }
        });
        let t0 = Instant::now();
        let rep = run_pair(&spec(0.0, AdaptConfig::default()), st, rt, &data, Some(&mut obs), None)
            .expect("congestion");
        outcome("congestion_0.5r", &rep, t0.elapsed().as_secs_f64(), &data)
    };

    let all = [&uniform, &ge, &ge_iid, &congestion];
    let mut table = BenchTable::new(
        "pacing",
        vec!["scenario", "passes", "fragments", "wall_s", "min_rate", "max_m"],
    );
    table.header();
    for o in all {
        table.row(
            o.name,
            vec![
                format!("{}", o.passes),
                format!("{}", o.fragments),
                format!("{:.3}", o.wall_s),
                format!("{:.0}", o.min_rate),
                format!("{}", o.max_m),
            ],
        );
    }
    table.save().unwrap();
    write_json(&all).expect("write BENCH_pacing.json");

    // --- Acceptance gates (the deterministic matrix of ISSUE 6) ---
    assert!(
        ge.min_rate >= 0.69 * RATE,
        "burst loss must sustain the rate, got min {:.0}",
        ge.min_rate
    );
    assert!(
        congestion.min_rate < 0.6 * RATE,
        "the policer must force a back-off, got min {:.0}",
        congestion.min_rate
    );
    assert!(
        ge.passes <= ge_iid.passes,
        "burst-aware solve ({}) must not need more passes than i.i.d. ({})",
        ge.passes,
        ge_iid.passes
    );
    println!(
        "\nge burst-aware {} passes (max m {}) vs iid {} passes (max m {}); \
         congestion settled at min {:.0} frag/s",
        ge.passes, ge.max_m, ge_iid.passes, ge_iid.max_m, congestion.min_rate
    );
    println!("pacing complete.");
}

/// Save the pacing matrix as JSON (CI uploads this artifact as
/// `BENCH_pacing`).
fn write_json(outcomes: &[&Outcome]) -> std::io::Result<PathBuf> {
    let dir = PathBuf::from("target/bench-results");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join("BENCH_pacing.json");
    let mut f = std::fs::File::create(&path)?;
    writeln!(f, "{{")?;
    writeln!(f, "  \"bench\": \"pacing\",")?;
    writeln!(f, "  \"streams\": {STREAMS},")?;
    writeln!(f, "  \"nominal_rate\": {RATE},")?;
    writeln!(f, "  \"mean_loss\": {LOSS},")?;
    writeln!(f, "  \"burst_len\": {BURST},")?;
    writeln!(f, "  \"scenarios\": [")?;
    for (i, o) in outcomes.iter().enumerate() {
        let rates: Vec<String> = o.rates.iter().map(|r| format!("{r:.1}")).collect();
        writeln!(f, "    {{")?;
        writeln!(f, "      \"name\": \"{}\",", o.name)?;
        writeln!(f, "      \"passes\": {},", o.passes)?;
        writeln!(f, "      \"fragments\": {},", o.fragments)?;
        writeln!(f, "      \"wall_s\": {:.4},", o.wall_s)?;
        writeln!(f, "      \"min_rate\": {:.1},", o.min_rate)?;
        writeln!(f, "      \"max_m\": {},", o.max_m)?;
        writeln!(f, "      \"rate_trajectory\": [{}]", rates.join(", "))?;
        writeln!(f, "    }}{}", if i + 1 < outcomes.len() { "," } else { "" })?;
    }
    writeln!(f, "  ]")?;
    writeln!(f, "}}")?;
    println!("[saved {}]", path.display());
    Ok(path)
}
