//! Fig. 3 — Error bounds of data received within a guaranteed
//! transmission time under static packet loss rates.
//!
//! For each λ, the deadline τ is the minimum Fig. 2 transfer time. The
//! Eq. 12-optimized per-level parity is compared against uniform-m
//! configurations over 100 runs: the paper's claim is that the optimized
//! plan stays within τ and lands at ε_3 almost always, while uniform
//! plans either blow the deadline (large m) or lose everything (small m).

use janus::metrics::bench::{bench_runs, bench_scale, BenchTable};
use janus::model::{optimize_deadline_paper, LevelSchedule, NetParams};
use janus::sim::{run_guaranteed_time, DeadlinePolicy, StaticLoss};

fn main() {
    let scale = bench_scale(10);
    let runs = bench_runs(100);
    let sched = if scale <= 1 {
        LevelSchedule::paper_nyx()
    } else {
        LevelSchedule::paper_nyx_scaled(scale)
    };
    // Paper §5.2.3 minimum times (Fig. 2 optima), scaled.
    let taus = [(19.0, 378.03), (383.0, 401.11), (957.0, 429.75)];

    for (lambda, tau_full) in taus {
        let tau = tau_full / scale as f64;
        let params = NetParams::paper_default(lambda);
        let ttl = 1.0 / params.r;
        let mut table = BenchTable::new(
            &format!("fig3_lambda{}", lambda as u64),
            vec!["config", "eps0", "eps1", "eps2", "eps3", "eps4", "overtime"],
        );
        table.header();

        let opt = optimize_deadline_paper(&params, &sched, tau).expect("feasible");
        let mut plans: Vec<(String, Vec<usize>)> =
            vec![(format!("optimized {:?}", opt.m), opt.m.clone())];
        for m in [0usize, 4, 8, 12, 16] {
            plans.push((format!("uniform m={m}"), vec![m; 4]));
        }

        for (label, plan) in plans {
            // Uniform plans may exceed τ: measure instead of skip.
            let mut counts = [0u32; 5]; // achieved ε index (0..4 levels)
            let mut overtime = 0u32;
            for seed in 0..runs {
                let mut loss = StaticLoss::with_ttl(lambda, 9_000 + seed as u64, ttl);
                let res = run_guaranteed_time(
                    &mut loss,
                    &params,
                    &sched,
                    f64::INFINITY, // run to completion; judge τ afterwards
                    &DeadlinePolicy::Static(plan.clone()),
                )
                .unwrap();
                counts[res.levels_recovered] += 1;
                if res.total_time > tau * 1.01 {
                    overtime += 1;
                }
            }
            table.row(
                label,
                (0..5)
                    .map(|i| counts[i].to_string())
                    .chain([format!("{overtime}/{runs}")])
                    .collect(),
            );
        }
        table.save().unwrap();

        // Shape check: the optimized plan must meet the deadline and
        // deliver ≥3 levels (ε_3) in the vast majority of runs.
        let mut ok = 0;
        for seed in 0..runs {
            let mut loss = StaticLoss::with_ttl(lambda, 9_000 + seed as u64, ttl);
            let res = run_guaranteed_time(
                &mut loss,
                &params,
                &sched,
                f64::INFINITY,
                &DeadlinePolicy::Static(opt.m.clone()),
            )
            .unwrap();
            if res.levels_recovered >= 3 && res.total_time <= tau * 1.01 {
                ok += 1;
            }
        }
        assert!(
            ok as f64 >= 0.9 * runs as f64,
            "λ={lambda}: optimized plan achieved ε_3-within-τ only {ok}/{runs}"
        );
    }
    println!("\nfig3 complete.");
}
