//! §5.2.2 — Parity-fragment generation rate `r_ec` vs m.
//!
//! Paper measurement (liberasurecode, n = 32, 4 096-B fragments):
//! 319 531 frag/s at m = 1 falling to 41 561 frag/s at m = 16. This bench
//! produces our codec's curve; the paper's conclusion to reproduce is
//! r_ec > r_link = 19 144 frag/s for every m, so the link (not encoding)
//! bounds the transmission rate.

use janus::erasure::sweep_ec_rates;
use janus::metrics::bench::BenchTable;

fn main() {
    let n = 32;
    let secs = std::env::var("JANUS_EC_SECS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.5);
    let mut table = BenchTable::new(
        "rs_throughput",
        vec!["m", "fragments_per_s", "data_MB_per_s", "vs_r_link"],
    );
    table.header();
    let rates = sweep_ec_rates(n, 16, 4096, secs);
    for r in &rates {
        table.row(
            format!("m={}", r.m),
            vec![
                format!("{:.0}", r.fragments_per_sec),
                format!("{:.1}", r.data_bytes_per_sec / 1e6),
                format!("{:.1}x", r.fragments_per_sec / 19_144.0),
            ],
        );
    }
    table.save().unwrap();

    // Shape checks from the paper's table.
    assert!(
        rates[0].fragments_per_sec > rates[15].fragments_per_sec,
        "rate must fall as m grows"
    );
    for r in &rates {
        assert!(
            r.fragments_per_sec > 19_144.0,
            "m={}: r_ec {:.0} < r_link — encode would bottleneck the wire",
            r.m,
            r.fragments_per_sec
        );
    }
    println!("\nrs_throughput complete: r_ec > r_link for all m (paper §5.2.2).");
}
