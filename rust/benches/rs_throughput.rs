//! §5.2.2 — Parity-fragment generation rate `r_ec` vs m, plus the
//! kernel-tier bandwidth gate (ISSUE 8).
//!
//! Paper measurement (liberasurecode, n = 32, 4 096-B fragments):
//! 319 531 frag/s at m = 1 falling to 41 561 frag/s at m = 16. This bench
//! produces our codec's curve; the paper's conclusion to reproduce is
//! r_ec > r_link = 19 144 frag/s for every m, so the link (not encoding)
//! bounds the transmission rate.
//!
//! The second half sweeps the fused strided encode across every kernel
//! tier the host supports (scalar → SSSE3 → AVX2) and against the
//! row-at-a-time reference, saving GB/s per (k, m, tier) to
//! `target/bench-results/BENCH_rs.json` (CI uploads it). Two gates:
//! fused ≥ 1.3× row-at-a-time on the best SIMD tier, and AVX2 ≥ 2×
//! scalar at (k=8, m=4). Hosts without the relevant ISA skip (never
//! fail) the corresponding gate.

use janus::erasure::kernel::{self, KernelTier};
use janus::erasure::{sweep_ec_rates, RsCode};
use janus::metrics::bench::BenchTable;
use std::io::Write;
use std::path::PathBuf;
use std::time::Instant;

/// Geometries swept by the kernel bench: the gate geometry (8, 4), the
/// paper's (28, 4), and a deep-parity point (16, 16).
const GEOMS: [(usize, usize); 3] = [(8, 4), (28, 4), (16, 16)];
const S: usize = 4096;

/// One measured point of the kernel sweep.
struct KernelRow {
    k: usize,
    m: usize,
    tier: KernelTier,
    fused_gbps: f64,
    rowwise_gbps: f64,
}

/// Best-of-3 strided-encode source bandwidth (GB/s of data encoded) on
/// a forced tier; `rowwise` selects the row-at-a-time reference path.
fn encode_gbps(
    code: &RsCode,
    k: usize,
    m: usize,
    secs: f64,
    tier: KernelTier,
    rowwise: bool,
) -> f64 {
    let mut buf = vec![0u8; (k + m) * S];
    for (i, b) in buf[..k * S].iter_mut().enumerate() {
        *b = (i * 131 % 251) as u8;
    }
    let mut best = 0.0f64;
    for _ in 0..3 {
        let t0 = Instant::now();
        let mut bytes = 0u64;
        loop {
            if rowwise {
                code.encode_strided_rowwise(&mut buf, S, tier).expect("encode");
            } else {
                code.encode_strided_tier(&mut buf, S, tier).expect("encode");
            }
            std::hint::black_box(&buf);
            bytes += (k * S) as u64;
            if t0.elapsed().as_secs_f64() >= secs {
                break;
            }
        }
        best = best.max(bytes as f64 / t0.elapsed().as_secs_f64() / 1e9);
    }
    best
}

/// Save the kernel sweep + gate verdicts as JSON (CI uploads this).
fn write_rs_json(
    rows: &[KernelRow],
    fused_speedup: Option<f64>,
    avx2_speedup: Option<f64>,
) -> std::io::Result<PathBuf> {
    let dir = PathBuf::from("target/bench-results");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join("BENCH_rs.json");
    let mut f = std::fs::File::create(&path)?;
    writeln!(f, "{{")?;
    writeln!(f, "  \"bench\": \"rs_kernels\",")?;
    writeln!(f, "  \"fragment_size_bytes\": {S},")?;
    writeln!(f, "  \"best_tier\": \"{}\",", kernel::best_supported().name())?;
    writeln!(f, "  \"rows\": [")?;
    for (i, r) in rows.iter().enumerate() {
        let comma = if i + 1 < rows.len() { "," } else { "" };
        writeln!(
            f,
            "    {{\"k\": {}, \"m\": {}, \"tier\": \"{}\", \
             \"fused_gbps\": {:.3}, \"rowwise_gbps\": {:.3}}}{comma}",
            r.k,
            r.m,
            r.tier.name(),
            r.fused_gbps,
            r.rowwise_gbps
        )?;
    }
    writeln!(f, "  ],")?;
    match fused_speedup {
        Some(v) => writeln!(f, "  \"fused_vs_rowwise\": {v:.3},")?,
        None => writeln!(f, "  \"fused_vs_rowwise\": null,")?,
    }
    match avx2_speedup {
        Some(v) => writeln!(f, "  \"avx2_vs_scalar\": {v:.3}")?,
        None => writeln!(f, "  \"avx2_vs_scalar\": null")?,
    }
    writeln!(f, "}}")?;
    println!("[saved {}]", path.display());
    Ok(path)
}

fn main() {
    let n = 32;
    let secs = std::env::var("JANUS_EC_SECS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.5);
    let mut table = BenchTable::new(
        "rs_throughput",
        vec!["m", "fragments_per_s", "data_MB_per_s", "vs_r_link"],
    );
    table.header();
    let rates = sweep_ec_rates(n, 16, 4096, secs);
    for r in &rates {
        table.row(
            format!("m={}", r.m),
            vec![
                format!("{:.0}", r.fragments_per_sec),
                format!("{:.1}", r.data_bytes_per_sec / 1e6),
                format!("{:.1}x", r.fragments_per_sec / 19_144.0),
            ],
        );
    }

    // --- Kernel-tier sweep (ISSUE 8 gate) ---
    let tiers = kernel::supported_tiers();
    let per_point = (secs / 4.0).clamp(0.02, 0.5);
    let mut rows: Vec<KernelRow> = Vec::new();
    for &(k, m) in &GEOMS {
        let code = RsCode::new(k, m).unwrap();
        for &tier in &tiers {
            let fused = encode_gbps(&code, k, m, per_point, tier, false);
            let rowwise = encode_gbps(&code, k, m, per_point, tier, true);
            table.row(
                format!("k={k} m={m} {}", tier.name()),
                vec![
                    "-".into(),
                    format!("{fused:.2} GB/s fused"),
                    format!("{:.2}x vs rowwise", fused / rowwise.max(1e-9)),
                ],
            );
            rows.push(KernelRow { k, m, tier, fused_gbps: fused, rowwise_gbps: rowwise });
        }
    }
    table.save().unwrap();

    let best = kernel::best_supported();
    let gate = |k: usize, m: usize, tier: KernelTier| {
        rows.iter().find(|r| r.k == k && r.m == m && r.tier == tier)
    };
    // Gate 1: fused ≥ 1.3× row-at-a-time on the best SIMD tier at the
    // gate geometry. Scalar-only hosts skip (fusion saves table reloads
    // that scalar code never pays for in the same way).
    let fused_speedup = if best > KernelTier::Scalar {
        let r = gate(8, 4, best).expect("gate geometry measured");
        Some(r.fused_gbps / r.rowwise_gbps.max(1e-9))
    } else {
        println!("[skip] fused-vs-rowwise gate: no SIMD tier on this host");
        None
    };
    // Gate 2: AVX2 ≥ 2× scalar on the fused encode at (8, 4). Skipped
    // (not failed) on hosts without AVX2.
    let avx2_speedup = if best >= KernelTier::Avx2 {
        let a = gate(8, 4, KernelTier::Avx2).expect("avx2 measured");
        let s = gate(8, 4, KernelTier::Scalar).expect("scalar measured");
        Some(a.fused_gbps / s.fused_gbps.max(1e-9))
    } else {
        println!("[skip] avx2-vs-scalar gate: AVX2 not supported on this host");
        None
    };
    write_rs_json(&rows, fused_speedup, avx2_speedup).unwrap();
    if let Some(v) = fused_speedup {
        assert!(
            v >= 1.3,
            "fused multi-row kernel regressed: {v:.2}x vs row-at-a-time (target ≥1.3x)"
        );
    }
    if let Some(v) = avx2_speedup {
        assert!(v >= 2.0, "AVX2 kernel regressed: {v:.2}x vs scalar (target ≥2x)");
    }

    // Shape checks from the paper's table.
    assert!(
        rates[0].fragments_per_sec > rates[15].fragments_per_sec,
        "rate must fall as m grows"
    );
    for r in &rates {
        assert!(
            r.fragments_per_sec > 19_144.0,
            "m={}: r_ec {:.0} < r_link — encode would bottleneck the wire",
            r.m,
            r.fragments_per_sec
        );
    }
    println!("\nrs_throughput complete: r_ec > r_link for all m (paper §5.2.2).");
}
