//! Property-based invariants of the protocol simulators and models
//! (mini-prop framework; proptest is not in the offline crate set).

use janus::model::params::{LevelSchedule, NetParams};
use janus::model::prob::{p_unrecoverable, p_unrecoverable_table};
use janus::model::time_model::{expected_total_time, num_ftgs, optimize_parity};
use janus::model::{
    expected_error, feasible_levels, optimize_deadline_exhaustive, transmission_time,
};
use janus::sim::{
    run_guaranteed_error, run_guaranteed_time, DeadlinePolicy, ParityPolicy, StaticLoss,
};
use janus::util::prop::{check, no_shrink, PropConfig};
use janus::util::Pcg64;

fn random_params(rng: &mut Pcg64) -> NetParams {
    NetParams {
        t: 0.001 + rng.next_f64() * 0.05,
        r: 1_000.0 + rng.next_f64() * 50_000.0,
        lambda: rng.next_f64() * 1_000.0,
        n: 2 * rng.range(2, 33), // even n, 4..=64
        s: 1 << rng.range(8, 13),
    }
}

fn random_sched(rng: &mut Pcg64) -> LevelSchedule {
    let levels = rng.range(1, 5);
    let mut sizes = Vec::new();
    let mut size = (1u64 << 16) + rng.next_below(1 << 18);
    let mut eps = Vec::new();
    let mut e = 0.01 * (1.0 + rng.next_f64());
    for _ in 0..levels {
        sizes.push(size);
        eps.push(e);
        size *= 2 + rng.next_below(3);
        e /= 5.0 + rng.next_f64() * 10.0;
    }
    LevelSchedule::new(sizes, eps)
}

#[test]
fn prop_p_unrecoverable_is_probability_and_monotone_in_m() {
    check(
        &PropConfig { cases: 80, ..Default::default() },
        |rng| {
            let p = random_params(rng);
            (p.t, p.r, p.lambda, p.n, p.s)
        },
        no_shrink,
        |&(t, r, lambda, n, s)| {
            let p = NetParams { t, r, lambda, n, s };
            let table = p_unrecoverable_table(&p, n / 2);
            for (m, &v) in table.iter().enumerate() {
                if !(0.0..=1.0).contains(&v) {
                    return Err(format!("p({m}) = {v} outside [0,1]"));
                }
            }
            for w in table.windows(2) {
                if w[1] > w[0] + 1e-12 {
                    return Err(format!("p not monotone: {table:?}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_expected_time_at_least_wire_time() {
    check(
        &PropConfig { cases: 80, ..Default::default() },
        |rng| {
            let p = random_params(rng);
            let bytes = (1u64 << 20) + rng.next_below(1 << 24);
            let m = rng.range(0, p.n / 2 + 1);
            (p.t, p.r, p.lambda, p.n, p.s, bytes, m)
        },
        no_shrink,
        |&(t, r, lambda, n, s, bytes, m)| {
            let p = NetParams { t, r, lambda, n, s };
            let groups = num_ftgs(bytes, &p, m);
            let p_loss = p_unrecoverable(&p, m);
            let total = expected_total_time(&p, groups, p_loss);
            let wire = t + (n as f64 * groups - 1.0) / r;
            if total + 1e-9 < wire {
                return Err(format!("E[T]={total} < wire time {wire}"));
            }
            if !total.is_finite() {
                return Err("E[T] not finite".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_optimizer_never_worse_than_any_candidate() {
    check(
        &PropConfig { cases: 30, ..Default::default() },
        |rng| {
            let p = random_params(rng);
            let bytes = (1u64 << 22) + rng.next_below(1 << 26);
            let probe_m = rng.range(0, p.n / 2 + 1);
            (p.t, p.r, p.lambda, p.n, p.s, bytes, probe_m)
        },
        no_shrink,
        |&(t, r, lambda, n, s, bytes, probe_m)| {
            let p = NetParams { t, r, lambda, n, s };
            let best = optimize_parity(&p, bytes);
            let probe_groups = num_ftgs(bytes, &p, probe_m);
            let probe =
                expected_total_time(&p, probe_groups, p_unrecoverable(&p, probe_m));
            if best.expected_time > probe + 1e-9 {
                return Err(format!(
                    "optimizer m={} ({}) worse than probe m={probe_m} ({probe})",
                    best.m, best.expected_time
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_deadline_solution_always_feasible() {
    check(
        &PropConfig { cases: 25, ..Default::default() },
        |rng| {
            let seed = rng.next_u64();
            seed
        },
        no_shrink,
        |&seed| {
            let mut rng = Pcg64::seeded(seed);
            let p = random_params(&mut rng);
            let sched = random_sched(&mut rng);
            let min_time = transmission_time(&p, &sched, &vec![0; sched.num_levels()]);
            let tau = min_time * (0.3 + rng.next_f64() * 2.0);
            match optimize_deadline_exhaustive(&p, &sched, tau) {
                Some(opt) => {
                    if opt.time > tau + 1e-9 {
                        return Err(format!("solution time {} > τ {tau}", opt.time));
                    }
                    if opt.m.len() != opt.levels {
                        return Err("plan length != levels".into());
                    }
                    let feas = feasible_levels(&p, &sched, tau);
                    if !feas.contains(&opt.levels) {
                        return Err(format!("levels {} not feasible {feas:?}", opt.levels));
                    }
                    // E[ε] within [min ε, 1].
                    if opt.expected_error > 1.0 + 1e-9 {
                        return Err(format!("E[ε] = {} > 1", opt.expected_error));
                    }
                    Ok(())
                }
                None => {
                    // Infeasible only if even l=1 with m=0 misses τ.
                    let t1 = transmission_time(&p, &sched, &[0]);
                    if t1 <= tau {
                        return Err(format!("τ={tau} feasible (t1={t1}) but solver said no"));
                    }
                    Ok(())
                }
            }
        },
    );
}

#[test]
fn prop_expected_error_is_convex_combination() {
    check(
        &PropConfig { cases: 60, ..Default::default() },
        |rng| rng.next_u64(),
        no_shrink,
        |&seed| {
            let mut rng = Pcg64::seeded(seed);
            let sched = random_sched(&mut rng);
            let l = sched.num_levels();
            let probs: Vec<f64> = (0..l).map(|_| rng.next_f64() * 0.2).collect();
            let groups: Vec<f64> = (0..l).map(|_| 1.0 + rng.next_f64() * 1e4).collect();
            let e = expected_error(&sched, &probs, &groups);
            let lo = sched.eps_with_levels(l);
            if !(lo - 1e-12..=1.0 + 1e-12).contains(&e) {
                return Err(format!("E[ε]={e} outside [{lo}, 1]"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_sim_guaranteed_error_always_delivers() {
    // Fundamental Alg. 1 invariant: whatever the loss rate, the transfer
    // terminates with every required FTG recovered (fragment accounting
    // balances).
    check(
        &PropConfig { cases: 12, ..Default::default() },
        |rng| {
            (
                rng.next_u64(),
                [19.0, 383.0, 957.0][rng.range(0, 3)],
                rng.range(0, 9),
            )
        },
        no_shrink,
        |&(seed, lambda, m)| {
            let p = NetParams::paper_default(lambda);
            let sched = LevelSchedule::paper_nyx_scaled(2000);
            let mut loss = StaticLoss::with_ttl(lambda, seed, 1.0 / p.r);
            let res = run_guaranteed_error(&mut loss, &p, &sched, 4, &ParityPolicy::Static(m));
            if !res.total_time.is_finite() || res.total_time <= 0.0 {
                return Err(format!("bad total time {}", res.total_time));
            }
            // Fragments sent ≥ data fragments needed.
            let data_frags = sched.total_bytes(4).div_ceil(p.s as u64);
            let min_sent = data_frags as f64 * (p.n as f64 / (p.n - m) as f64);
            if (res.fragments_sent as f64) < min_sent * 0.999 {
                return Err(format!(
                    "sent {} < minimum {min_sent:.0}",
                    res.fragments_sent
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_sim_deadline_never_exceeds_tau_meaningfully() {
    check(
        &PropConfig { cases: 12, ..Default::default() },
        |rng| (rng.next_u64(), [19.0, 383.0, 957.0][rng.range(0, 3)]),
        no_shrink,
        |&(seed, lambda)| {
            let p = NetParams::paper_default(lambda);
            let sched = LevelSchedule::paper_nyx_scaled(2000);
            let tau = 0.25; // generous for the scaled workload
            let mut loss = StaticLoss::with_ttl(lambda, seed, 1.0 / p.r);
            match run_guaranteed_time(
                &mut loss,
                &p,
                &sched,
                tau,
                &DeadlinePolicy::Adaptive { t_w: 0.05, initial_lambda: lambda },
            ) {
                Some(res) => {
                    if res.total_time > tau * 1.05 + 2.0 * p.t {
                        return Err(format!("time {} ≫ τ {tau}", res.total_time));
                    }
                    if res.levels_recovered > res.levels_sent {
                        return Err("recovered more levels than sent".into());
                    }
                    // Achieved ε consistent with recovered prefix.
                    let want = sched.eps_with_levels(res.levels_recovered);
                    if (res.achieved_eps - want).abs() > 1e-12 {
                        return Err(format!(
                            "ε mismatch: {} vs {want}",
                            res.achieved_eps
                        ));
                    }
                    Ok(())
                }
                None => Err("τ unexpectedly infeasible".into()),
            }
        },
    );
}
