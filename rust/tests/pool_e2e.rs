//! End-to-end matrix for the multi-stream pooled path of the
//! `janus::api` facade over the deterministic testkit: byte-exact
//! delivery at loss rates {0, 1%, 5%, 20%}, λ̂ convergence to the
//! injected loss rate, and bit-identical transfer traces for identical
//! seeds.

use janus::api::{run_pair, Contract, Dataset, StagedTransport, TransferReport, TransferSpec};
use janus::model::NetParams;
use janus::testkit::{loss_transport_pair, LossTrace};
use janus::util::Pcg64;
use std::time::Duration;

const STREAMS: usize = 4;
const RATE: f64 = 200_000.0;

fn sized_dataset(seed: u64, scale: usize) -> Dataset {
    let mut rng = Pcg64::seeded(seed);
    let sizes = [60_000usize * scale, 250_000 * scale, 500_000 * scale];
    let eps = vec![0.004, 0.0005, 0.0000001];
    Dataset::new(
        sizes
            .iter()
            .map(|&sz| {
                let mut v = vec![0u8; sz];
                rng.fill_bytes(&mut v);
                v
            })
            .collect(),
        eps,
    )
    .unwrap()
}

fn dataset(seed: u64) -> Dataset {
    sized_dataset(seed, 1)
}

fn spec(initial_lambda: f64) -> TransferSpec {
    TransferSpec::builder()
        .contract(Contract::Fidelity(1e-7))
        .streams(STREAMS)
        .net(NetParams { t: 0.0005, r: RATE, lambda: 0.0, n: 32, s: 1024 })
        .initial_lambda(initial_lambda)
        .lambda_window(0.25)
        .idle_timeout(Duration::from_secs(10))
        .max_duration(Duration::from_secs(120))
        .build()
        .unwrap()
}

fn run_with(
    data: &Dataset,
    initial_lambda: f64,
    transports: (StagedTransport, StagedTransport),
) -> TransferReport {
    let (sender_t, receiver_t) = transports;
    let report = run_pair(&spec(initial_lambda), sender_t, receiver_t, data, None, None).unwrap();
    // Byte-exactness is part of every matrix point.
    for (li, (got, want)) in report.received.levels.iter().zip(&data.levels).enumerate() {
        assert_eq!(
            got.as_ref().expect("level must be delivered"),
            want,
            "level {li} bytes differ"
        );
    }
    assert_eq!(report.received.levels_recovered, data.levels.len());
    report
}

fn run_at(loss: f64, seed: u64, initial_lambda: f64) -> TransferReport {
    run_at_scaled(loss, seed, initial_lambda, 1)
}

fn run_at_scaled(loss: f64, seed: u64, initial_lambda: f64, scale: usize) -> TransferReport {
    let data = sized_dataset(0xDA7A ^ seed, scale);
    let transports =
        loss_transport_pair(STREAMS, |w| LossTrace::seeded(loss, seed ^ (w as u64 + 1) * 0x9E37));
    run_with(&data, initial_lambda, transports)
}

#[test]
fn matrix_lossless_delivers_in_one_pass() {
    let rep = run_at(0.0, 11, 0.0);
    let s = rep.sent.pooled().unwrap();
    let r = rep.received.pooled().unwrap();
    assert_eq!(rep.sent.passes, 0, "no loss ⇒ no retransmission");
    assert_eq!(s.trace.len(), 1);
    assert_eq!(s.trace[0].m, 0, "λ̂=0 ⇒ Eq.8 picks m=0");
    assert_eq!(s.trace[0].lambda_hat, 0.0);
    assert_eq!(r.trace.len(), 1);
    assert_eq!(r.trace[0].expected, r.trace[0].received);
    assert_eq!(rep.received.groups_recovered, 0, "nothing to RS-recover");
}

#[test]
fn matrix_one_percent_loss() {
    // Honest initial estimate: λ₀ = f · N · r.
    let rep = run_at(0.01, 22, 0.01 * RATE * STREAMS as f64);
    let s = rep.sent.pooled().unwrap();
    assert!(s.trace[0].m >= 1, "1% loss should buy parity, m={}", s.trace[0].m);
    // Mostly recovered by parity in-pass; a few groups may need retries.
    assert!(rep.sent.passes <= 3, "1% loss needed {} passes", rep.sent.passes);
    assert!(rep.received.groups_recovered > 0 || rep.sent.passes > 0);
}

#[test]
fn matrix_five_percent_loss() {
    let rep = run_at(0.05, 33, 0.05 * RATE * STREAMS as f64);
    assert!(rep.sent.passes <= 6, "5% loss needed {} passes", rep.sent.passes);
}

#[test]
fn matrix_twenty_percent_loss() {
    let rep = run_at(0.20, 44, 0.20 * RATE * STREAMS as f64);
    // Brutal loss: correctness (asserted in run_with) is the headline;
    // convergence must still be quick thanks to λ̂-adapted parity.
    assert!(rep.sent.passes <= 12, "20% loss needed {} passes", rep.sent.passes);
}

#[test]
fn lambda_hat_converges_to_injected_rate() {
    // Start from a WRONG initial estimate (0): after pass 0 the shared
    // estimator must land near f · N · r. The tolerance is statistical
    // (Bernoulli over the pass-0 fragment count), so use a 10×-scaled
    // dataset (~8k fragments): 0.40 relative tolerance is then ≥ 3.5σ
    // at every loss rate tested.
    for (loss, seed) in [(0.01, 5u64), (0.05, 6), (0.20, 7)] {
        let rep = run_at_scaled(loss, seed, 0.0, 10);
        let s = rep.sent.pooled().unwrap();
        let r = rep.received.pooled().unwrap();
        let expect = loss * RATE * STREAMS as f64;
        let got = s.trace[0].lambda_hat;
        let rel = (got - expect).abs() / expect;
        assert!(
            rel < 0.40,
            "loss={loss}: λ̂={got:.0} vs expected {expect:.0} (rel {rel:.2})"
        );
        // Internal consistency: λ̂ is exactly the surviving-fraction
        // estimate over the aggregate nominal rate.
        let (e, rc) = (r.trace[0].expected, r.trace[0].received);
        let reconstructed = (1.0 - rc as f64 / e as f64) * RATE * STREAMS as f64;
        assert!(
            (got - reconstructed).abs() < 1e-6,
            "λ̂ {got} vs reconstructed {reconstructed}"
        );
    }
}

#[test]
fn lambda_mismeasure_heals_after_first_pass() {
    // Lie badly about λ₀ (claim lossless on a 5% link): pass 0 goes out
    // with m=0, the barrier measures the truth, and the retransmission
    // pass gets Eq.8-sized parity. The transfer still completes exactly.
    let rep = run_at(0.05, 55, 0.0);
    let s = rep.sent.pooled().unwrap();
    assert_eq!(s.trace[0].m, 0, "λ₀=0 ⇒ first pass unprotected");
    assert!(rep.sent.passes >= 1, "5% loss with m=0 must retransmit");
    assert!(
        s.trace[1].m >= 1,
        "measured λ̂ must buy parity on retransmission: {:?}",
        s.trace.iter().map(|p| p.m).collect::<Vec<_>>()
    );
}

#[test]
fn identical_seeds_produce_identical_traces() {
    // The determinism contract of the testkit + pass-barrier design:
    // same seeds ⇒ the full sender AND receiver traces are equal, at
    // every loss rate in the matrix.
    for loss in [0.0, 0.01, 0.05, 0.20] {
        let r1 = run_at(loss, 99, 0.0);
        let r2 = run_at(loss, 99, 0.0);
        assert_eq!(
            r1.sent.pooled().unwrap().trace,
            r2.sent.pooled().unwrap().trace,
            "sender trace diverged at loss={loss}"
        );
        assert_eq!(
            r1.received.pooled().unwrap().trace,
            r2.received.pooled().unwrap().trace,
            "receiver trace diverged at loss={loss}"
        );
        assert_eq!(r1.sent.fragments_sent, r2.sent.fragments_sent);
        assert_eq!(r1.sent.lambda_history, r2.sent.lambda_history);
        assert_eq!(r1.received.fragments_received, r2.received.fragments_received);
        assert_eq!(r1.received.groups_recovered, r2.received.groups_recovered);
    }
}

#[test]
fn pooled_deadline_trace_determinism_includes_shed_decisions() {
    // The pool's core invariant extends to the Deadline contract: the
    // pass-barrier τ accounting (virtual clock, Eq. 12 re-solves, shed
    // decisions) is a pure function of (config, dataset, seeds), so two
    // identical runs produce bit-identical traces *including* the
    // `PassRecord::shed` entries — and the receiver certifies exactly
    // the advertisement the sheds left behind.
    let run = |tau: f64| {
        let data = sized_dataset(0x5EED, 1);
        let spec = TransferSpec::builder()
            .contract(Contract::Deadline(tau))
            .streams(STREAMS)
            .net(NetParams { t: 0.0005, r: RATE, lambda: 0.0, n: 32, s: 1024 })
            .initial_lambda(0.0)
            .lambda_window(0.25)
            .idle_timeout(Duration::from_secs(10))
            .max_duration(Duration::from_secs(120))
            .build()
            .unwrap();
        let (st, rt) = loss_transport_pair(STREAMS, |w| {
            LossTrace::seeded(0.20, 0xD1CE ^ (w as u64 + 1) * 0x9E37)
        });
        run_pair(&spec, st, rt, &data, None, None).unwrap()
    };
    // τ ≈ 1.4 × the unprotected pass-0 air time: after 20% of pass 0
    // dies, the residual budget forces sheds at the barrier.
    let frags: f64 = [60_000usize, 250_000, 500_000]
        .iter()
        .map(|&sz| sz.div_ceil(1024) as f64)
        .sum();
    let tau = 1.4 * (0.0005 + frags / (STREAMS as f64 * RATE));
    let r1 = run(tau);
    let r2 = run(tau);
    assert_eq!(r1.sent.pooled().unwrap().trace, r2.sent.pooled().unwrap().trace);
    assert_eq!(
        r1.received.pooled().unwrap().trace,
        r2.received.pooled().unwrap().trace
    );
    assert_eq!(r1.sent.deadline(), r2.sent.deadline());
    let dl = r1.sent.deadline().expect("deadline outcome");
    assert!(
        r1.sent.pooled().unwrap().trace.iter().any(|p| !p.shed.is_empty()),
        "tight τ under 20% loss must shed: {dl:?}"
    );
    assert!(dl.met, "sheds keep the virtual clock inside τ: {dl:?}");
    assert!(
        (r1.received.achieved_eps - dl.advertised_eps).abs() < 1e-15,
        "receiver ε {} vs advertised {}",
        r1.received.achieved_eps,
        dl.advertised_eps
    );
}

#[test]
fn decode_worker_count_never_changes_delivered_bytes() {
    // The pooled receiver's RS recovery is batched across a CodingPool
    // (`reconstruct_levels` → `RsCode::reconstruct_batch`); the
    // erasure::par determinism contract promises byte-identical delivery
    // for any worker count — including zero, where the submitting thread
    // drains the whole queue itself.
    let run = |workers: &str| {
        std::env::set_var("JANUS_POOL_DECODE_WORKERS", workers);
        let rep = run_at(0.05, 4242, 0.05 * RATE * STREAMS as f64);
        std::env::remove_var("JANUS_POOL_DECODE_WORKERS");
        rep
    };
    let r0 = run("0");
    let r3 = run("3");
    assert!(
        r0.received.groups_recovered > 0,
        "matrix point must actually exercise RS recovery"
    );
    assert_eq!(r0.received.groups_recovered, r3.received.groups_recovered);
    assert_eq!(
        r0.received.levels, r3.received.levels,
        "delivered bytes must not depend on the decode worker count"
    );
    assert_eq!(
        r0.received.pooled().unwrap().trace,
        r3.received.pooled().unwrap().trace
    );
}

#[test]
fn different_seeds_produce_different_traces_under_loss() {
    // Sanity for the determinism assertion above: the trace actually
    // depends on the loss realization (i.e. the equality test is not
    // vacuously comparing constants).
    let r1 = run_at(0.05, 101, 0.0);
    let r2 = run_at(0.05, 202, 0.0);
    assert_ne!(
        r1.sent.pooled().unwrap().trace,
        r2.sent.pooled().unwrap().trace,
        "5% loss with different seeds should differ somewhere"
    );
}

#[test]
fn per_stream_loss_asymmetry_is_handled() {
    // Stream 2 loses 30% while others are clean — the shared estimator
    // sees the aggregate, and the lost FTGs (all from one stream's
    // shard) still converge via re-sharded retransmission.
    let data = dataset(0xA5);
    let transports = loss_transport_pair(STREAMS, |w| {
        if w == 2 {
            LossTrace::seeded(0.30, 777)
        } else {
            LossTrace::None
        }
    });
    let rep = run_with(&data, 0.0, transports);
    // Aggregate λ̂ ≈ (0.30 / 4) · N·r.
    let expect = 0.30 / STREAMS as f64 * RATE * STREAMS as f64;
    let got = rep.sent.pooled().unwrap().trace[0].lambda_hat;
    assert!(
        (got - expect).abs() / expect < 0.40,
        "asymmetric λ̂ {got:.0} vs {expect:.0}"
    );
}

#[test]
fn phased_loss_trace_drives_adaptation() {
    // Virtual-time regime change: pass 0 mostly clean, the retransmitted
    // tail heavily lossy. Transfer must still complete byte-exactly.
    let data = dataset(0xB6);
    let transports = loss_transport_pair(STREAMS, |w| {
        LossTrace::phased(vec![(100, 0.002), (100, 0.15)], 1000 + w as u64)
    });
    let rep = run_with(&data, 0.0, transports);
    assert!(rep.sent.duration > 0.0);
}

#[test]
fn codec_volume_survives_the_pooled_lossy_matrix() {
    // The codec path rides the pooled engine untouched: rungs are just
    // levels on the wire. 5% loss, 4 streams, byte-exact per delivered
    // segment, and the receive side certifies the contracted ε.
    use janus::api::CodecConfig;
    use janus::refactor::{generate, GrfConfig};

    let vol = generate(32, &GrfConfig::default(), 0xC0DEC);
    let cfg = CodecConfig { levels: 4, ladder: vec![4e-3, 5e-4, 8e-5], max_planes: 24 };
    let data = Dataset::from_volume(&vol, &cfg).unwrap();
    let contracted = *data.eps.last().unwrap();
    let spec = TransferSpec::builder()
        .contract(Contract::Fidelity(contracted))
        .streams(STREAMS)
        .net(NetParams { t: 0.0005, r: RATE, lambda: 0.0, n: 32, s: 1024 })
        .initial_lambda(0.05 * RATE * STREAMS as f64)
        .lambda_window(0.25)
        .idle_timeout(Duration::from_secs(10))
        .max_duration(Duration::from_secs(120))
        .build()
        .unwrap();
    let (sender_t, receiver_t) =
        loss_transport_pair(STREAMS, |w| LossTrace::seeded(0.05, 0xC0DEC ^ (w as u64 + 1)));
    let rep = run_pair(&spec, sender_t, receiver_t, &data, None, None).unwrap();
    for (li, (got, want)) in rep.received.levels.iter().zip(&data.levels).enumerate() {
        assert_eq!(got.as_ref().expect("rung delivered"), want, "rung {li} differs");
    }
    let codec = rep.received.codec.as_ref().expect("codec summary attached");
    assert_eq!(codec.rungs_decoded, data.levels.len());
    assert!(codec.achieved_eps <= contracted + 1e-15);
    let out = rep.received.decode_volume().expect("codec stream").expect("decodes");
    assert!(
        vol.linf_rel_error(&out.volume) <= out.achieved_eps + 1e-12,
        "certified bound must hold against ground truth"
    );
}
