//! Datagram-level scenario tests for the sans-IO machines: a
//! single-threaded virtual-clock harness feeds [`SenderMachine`] /
//! [`ReceiverMachine`] one datagram at a time through scripted loss,
//! reordering, duplication and mid-transfer RTT steps — no sockets, no
//! threads, no sleeps. The blocking engines run the same seeds over real
//! channels to pin trace equivalence.

use janus::api::{AdaptConfig, Contract};
use janus::coordinator::packet::is_fragment;
use janus::coordinator::{
    run_receiver, run_sender, Packet, PacketView, ReceiverConfig, SenderConfig,
};
use janus::engine::{ReceiverMachine, SenderMachine};
use janus::erasure::Backend;
use janus::model::NetParams;
use janus::testkit::{FragmentLossChannel, LossTrace};
use janus::transport::channel::mem_pair;
use janus::util::Pcg64;
use std::collections::VecDeque;
use std::time::{Duration, Instant};

const RATE: f64 = 50_000.0;

fn scfg(lambda0: f64) -> SenderConfig {
    SenderConfig {
        net: NetParams { t: 0.002, r: RATE, lambda: 0.0, n: 32, s: 1024 },
        contract: Contract::Fidelity(1e-7),
        initial_lambda: lambda0,
        max_duration: Duration::from_secs(600),
        plane_cuts: vec![],
        adapt: AdaptConfig::fixed(),
    }
}

fn rcfg() -> ReceiverConfig {
    ReceiverConfig {
        // Suppress λ windows: virtual and wall clocks tick differently,
        // and the equivalence test needs both engines update-free.
        t_w: 1e9,
        idle_timeout: Duration::from_secs(300),
        max_duration: Duration::from_secs(600),
    }
}

fn payload(seed: u64, n: usize) -> Vec<u8> {
    let mut rng = Pcg64::seeded(seed);
    let mut v = vec![0u8; n];
    rng.fill_bytes(&mut v);
    v
}

/// Deterministic single-thread network: two one-way pipes with settable
/// latency, fragment loss by ordinal trace or by (pass, seq) predicate,
/// optional adjacent-pair reordering and every-Nth duplication on the
/// sender→receiver path. Control datagrams are reliable, like every
/// loss fixture in the repo.
struct Net {
    now: Instant,
    latency: Duration,
    s2r: VecDeque<(Instant, Vec<u8>)>,
    r2s: VecDeque<(Instant, Vec<u8>)>,
    trace: LossTrace,
    drop_fn: Option<Box<dyn FnMut(u32, u64) -> bool>>,
    frag_tick: u64,
    reorder: bool,
    held: Option<(Instant, Vec<u8>)>,
    dup_every: Option<u64>,
}

impl Net {
    fn new(latency: Duration, trace: LossTrace) -> Net {
        Net {
            now: Instant::now(),
            latency,
            s2r: VecDeque::new(),
            r2s: VecDeque::new(),
            trace,
            drop_fn: None,
            frag_tick: 0,
            reorder: false,
            held: None,
            dup_every: None,
        }
    }

    fn send_s2r(&mut self, buf: &[u8]) {
        if is_fragment(buf) {
            let tick = self.frag_tick;
            self.frag_tick += 1;
            let drop = match &mut self.drop_fn {
                Some(f) => {
                    let (pass, seq) = match PacketView::decode(buf) {
                        Ok(PacketView::Fragment(v)) => (v.header.pass, v.header.seq),
                        _ => (0, 0),
                    };
                    f(pass, seq)
                }
                None => self.trace.drop_at(tick),
            };
            if drop {
                return;
            }
            let at = self.now + self.latency;
            let dup = self.dup_every.map_or(false, |n| tick % n == n - 1);
            if self.reorder {
                match self.held.take() {
                    // Second of a pair: it arrives first, its earlier
                    // partner a hair later — a genuine swap on the wire.
                    Some((_, first)) => {
                        self.s2r.push_back((at, buf.to_vec()));
                        self.s2r.push_back((at + Duration::from_micros(1), first));
                    }
                    None => {
                        self.held = Some((at, buf.to_vec()));
                        return;
                    }
                }
            } else {
                self.s2r.push_back((at, buf.to_vec()));
            }
            if dup {
                self.s2r.push_back((at, buf.to_vec()));
            }
            return;
        }
        // Control: flush any held fragment so a barrier marker never
        // overtakes the data it fences.
        if let Some(h) = self.held.take() {
            self.s2r.push_back(h);
        }
        self.s2r.push_back((self.now + self.latency, buf.to_vec()));
    }

    fn send_r2s(&mut self, buf: &[u8]) {
        self.r2s.push_back((self.now + self.latency, buf.to_vec()));
    }

    /// Every queued datagram due at or before `now`, in queue order (a
    /// latency drop may legitimately deliver a late packet first: UDP).
    fn due(q: &mut VecDeque<(Instant, Vec<u8>)>, now: Instant) -> Vec<Vec<u8>> {
        let mut out = Vec::new();
        let mut rest = VecDeque::new();
        while let Some((at, buf)) = q.pop_front() {
            if at <= now {
                out.push(buf);
            } else {
                rest.push_back((at, buf));
            }
        }
        *q = rest;
        out
    }

    fn next_arrival(&self) -> Option<Instant> {
        self.s2r.iter().chain(self.r2s.iter()).map(|&(at, _)| at).min()
    }
}

/// Pump both machines over the scripted network until both finish.
/// `hook` runs each iteration (the RTT-step test mutates latency there).
/// Returns the virtual duration.
fn run(
    net: &mut Net,
    s: &mut SenderMachine,
    r: &mut ReceiverMachine,
    mut hook: impl FnMut(&mut Net, &SenderMachine),
) -> Duration {
    let start = net.now;
    let mut out = Vec::new();
    let mut steps = 0u64;
    while !(s.is_finished() && r.is_finished()) {
        steps += 1;
        assert!(steps < 10_000_000, "harness stalled");
        hook(net, s);
        let now = net.now;
        let mut progressed = false;
        for buf in Net::due(&mut net.s2r, now) {
            r.handle_datagram(&buf, now);
            progressed = true;
        }
        for buf in Net::due(&mut net.r2s, now) {
            s.handle_datagram(&buf, now);
            progressed = true;
        }
        while s.poll_transmit(&mut out, now) {
            net.send_s2r(&out);
            progressed = true;
        }
        while r.poll_transmit(&mut out, now) {
            net.send_r2s(&out);
            progressed = true;
        }
        if progressed {
            continue;
        }
        // Idle: jump the virtual clock to the next event. Deliveries are
        // handled before transmissions next iteration, so a reply that
        // lands exactly on a retry deadline wins the race (and gives the
        // RTT estimator its clean sample).
        let mut next = net.next_arrival();
        for cand in [s.poll_timeout(), r.poll_timeout()] {
            next = match (next, cand) {
                (Some(a), Some(b)) => Some(a.min(b)),
                (a, b) => a.or(b),
            };
        }
        let next = next.expect("idle with no pending event: deadlock");
        // Strictly advance — a deadline may sit exactly on `now`.
        net.now = next.max(now + Duration::from_nanos(100));
        s.handle_timeout(net.now);
        r.handle_timeout(net.now);
    }
    net.now.saturating_duration_since(start)
}

fn assert_delivered(report: &janus::coordinator::ReceiverReport, data: &[Vec<u8>]) {
    for (li, want) in data.iter().enumerate() {
        assert_eq!(
            report.levels[li].as_deref(),
            Some(&want[..]),
            "level {li} bytes differ"
        );
    }
    assert_eq!(report.levels_recovered, data.len());
}

#[test]
fn machines_roundtrip_losslessly() {
    let data = vec![payload(1, 40_000), payload(2, 80_000)];
    let eps = vec![1e-3, 1e-7];
    let mut net = Net::new(Duration::from_millis(2), LossTrace::None);
    let mut s = SenderMachine::new(&scfg(0.0), &data, &eps, net.now).unwrap();
    let mut r = ReceiverMachine::new(&rcfg(), net.now);
    let dur = run(&mut net, &mut s, &mut r, |_, _| {});
    assert!(!s.is_failed(), "sender failed");
    assert!(!r.is_failed(), "receiver failed");
    let sr = s.into_report().unwrap();
    assert_eq!(sr.passes, 0, "lossless transfer needs no retransmission");
    assert_delivered(&r.into_report().unwrap(), &data);
    assert!(dur < Duration::from_secs(30), "virtual duration {dur:?}");
}

#[test]
fn scripted_loss_reorder_duplication_still_byte_exact() {
    let data = vec![payload(7, 120_000)];
    let eps = vec![1e-7];
    // Scattered singles plus a 16-fragment burst that no pass-0 parity
    // survives; beyond the script, everything delivers.
    let mut script = vec![false; 400];
    for d in script.iter_mut().skip(10).step_by(17) {
        *d = true;
    }
    for d in script.iter_mut().take(56).skip(40) {
        *d = true;
    }
    let mut net = Net::new(Duration::from_millis(2), LossTrace::Script(script));
    net.reorder = true;
    net.dup_every = Some(9);
    let mut s = SenderMachine::new(&scfg(0.05 * RATE), &data, &eps, net.now).unwrap();
    let mut r = ReceiverMachine::new(&rcfg(), net.now);
    run(&mut net, &mut s, &mut r, |_, _| {});
    assert!(!s.is_failed(), "sender failed");
    assert!(!r.is_failed(), "receiver failed");
    let sr = s.into_report().unwrap();
    assert!(sr.passes >= 1, "the burst must force a retransmission pass");
    assert_delivered(&r.into_report().unwrap(), &data);
}

#[test]
fn machine_trace_matches_blocking_engine_under_identical_loss() {
    let data = vec![payload(0xE0, 96_000)];
    let eps = vec![1e-7];
    let seed = 0xBEEF;
    let frac = 0.15;
    let cfg = scfg(frac * RATE);
    let rc_cfg = rcfg();

    // Blocking reference: real channels, real threads, loss decided by
    // the same seeded trace over the same fragment ordinals.
    let (sc, rc) = mem_pair();
    let mut lossy = FragmentLossChannel::new(sc, LossTrace::seeded(frac, seed));
    let thread_cfg = rc_cfg.clone();
    let join = std::thread::spawn(move || {
        let mut rc = rc;
        run_receiver(&mut rc, &thread_cfg).unwrap()
    });
    let blocking_sent = run_sender(&mut lossy, &cfg, &data, &eps).unwrap();
    let blocking_recv = join.join().unwrap();
    assert_delivered(&blocking_recv, &data);

    // Machine run, same seed, virtual clock.
    let mut net = Net::new(Duration::from_millis(2), LossTrace::seeded(frac, seed));
    let mut s = SenderMachine::new(&cfg, &data, &eps, net.now).unwrap();
    let mut r = ReceiverMachine::new(&rc_cfg, net.now);
    run(&mut net, &mut s, &mut r, |_, _| {});
    let machine_sent = s.into_report().unwrap();
    let machine_recv = r.into_report().unwrap();
    assert_delivered(&machine_recv, &data);

    // Identical seeds ⇒ identical wire trace, thread structure aside.
    assert_eq!(machine_sent.passes, blocking_sent.passes, "pass count");
    assert_eq!(
        machine_sent.fragments_sent, blocking_sent.fragments_sent,
        "fragments offered to the wire"
    );
    assert_eq!(
        machine_sent.data_fragments, blocking_sent.data_fragments,
        "data fragments"
    );
    assert_eq!(
        machine_recv.fragments_received, blocking_recv.fragments_received,
        "fragments delivered"
    );
    assert_eq!(
        machine_recv.groups_recovered, blocking_recv.groups_recovered,
        "groups needing RS recovery"
    );
}

#[test]
fn rtt_step_reconverges_without_retry_storm() {
    let data = vec![payload(3, 100_000)];
    let eps = vec![1e-7];
    // λ₀ = 0 plans zero parity, and the predicate kills every third
    // fragment through pass 2: passes 0–2 each lose all their groups,
    // pass 3 runs clean — exactly three retransmission passes, four
    // barriers, deterministic with no seeds.
    let mut net = Net::new(Duration::from_millis(2), LossTrace::None);
    net.drop_fn = Some(Box::new(|pass, seq| pass < 3 && seq % 3 == 0));
    let mut s = SenderMachine::new(&scfg(0.0), &data, &eps, net.now).unwrap();
    let mut r = ReceiverMachine::new(&rcfg(), net.now);
    // Step the path latency 2 ms → 40 ms once the first retransmission
    // pass begins: every barrier after the step answers in 80 ms, four
    // times the sender's converged RTO.
    let stepped = Duration::from_millis(40);
    run(&mut net, &mut s, &mut r, |net, s| {
        if s.pass() >= 1 {
            net.latency = stepped;
        }
    });
    assert!(!s.is_failed(), "sender failed");
    assert!(!r.is_failed(), "receiver failed");
    let rto = s.rto();
    let eop = s.eop_sends();
    let sr = s.into_report().unwrap();
    assert_eq!(sr.passes, 3, "drop predicate fixes the pass count");
    assert_delivered(&r.into_report().unwrap(), &data);
    // RFC 6298 re-convergence: the RTO covers the stepped 80 ms barrier
    // round trip again (it was ~20 ms before the step).
    assert!(rto >= 0.08, "rto {rto} must re-converge past the 80 ms RTT");
    // Karn + exponential backoff keep retries bounded: one EndOfPass per
    // barrier plus a couple of backoff probes at the step — a storm
    // would burn EOP_TRIES-scale bursts on every post-step barrier.
    let passes = u64::from(sr.passes);
    assert!(
        eop <= passes + 6,
        "retry storm: {eop} EndOfPass sends over {passes} retransmission passes"
    );
}

#[test]
fn fountain_backend_is_barrier_free_and_byte_exact_under_loss() {
    // The rateless acceptance matrix: random loss at {0, 1, 5, 20}% plus
    // Gilbert-Elliott bursts. Every run must deliver byte-exact with the
    // pass-barrier machinery *never engaging* — no EndOfPass, no
    // LostList on the wire, zero retransmission passes — because repair
    // symbols stream until the receiver's GroupAcks say stop.
    let data = vec![payload(11, 40_000), payload(12, 80_000)];
    let eps = vec![1e-3, 1e-7];
    let traces: Vec<(&str, LossTrace)> = vec![
        ("lossless", LossTrace::None),
        ("1% random", LossTrace::seeded(0.01, 0xA1)),
        ("5% random", LossTrace::seeded(0.05, 0xA2)),
        ("20% random", LossTrace::seeded(0.20, 0xA3)),
        ("5% in bursts of 8", LossTrace::gilbert_elliott(0.05, 8.0, RATE, 0xA4)),
    ];
    for (name, trace) in traces {
        let mut net = Net::new(Duration::from_millis(2), trace);
        let mut s = SenderMachine::with_backend(
            &scfg(0.05 * RATE),
            &data,
            &eps,
            Backend::Fountain,
            net.now,
        )
        .unwrap();
        let mut r = ReceiverMachine::new(&rcfg(), net.now);
        // Loss injection keys on `is_fragment`, which covers repair
        // symbols too — the repair stream itself rides the lossy path.
        let mut barrier_pkt: Option<&'static str> = None;
        run(&mut net, &mut s, &mut r, |net, _| {
            for (_, buf) in net.s2r.iter().chain(net.r2s.iter()) {
                match Packet::decode(buf) {
                    Ok(Packet::EndOfPass { .. }) => barrier_pkt = Some("EndOfPass"),
                    Ok(Packet::LostList { .. }) => barrier_pkt = Some("LostList"),
                    _ => {}
                }
            }
        });
        assert!(!s.is_failed(), "{name}: sender failed");
        assert!(!r.is_failed(), "{name}: receiver failed");
        assert_eq!(s.eop_sends(), 0, "{name}: fountain sender sent EndOfPass");
        assert_eq!(barrier_pkt, None, "{name}: barrier packet on the wire");
        let sr = s.into_report().unwrap();
        assert_eq!(sr.passes, 0, "{name}: fountain transfer counted a pass");
        assert_delivered(&r.into_report().unwrap(), &data);
    }
}

#[test]
fn explicit_rs_backend_matches_the_default_constructor_trace() {
    // `Backend::Rs` is the default: selecting it explicitly must leave
    // the wire trace byte-identical to `SenderMachine::new` under the
    // same seeded loss — the backend seam adds a dispatch point, not a
    // behavior change.
    let data = vec![payload(21, 96_000)];
    let eps = vec![1e-7];
    let mut run_one = |explicit: bool| {
        let mut net = Net::new(Duration::from_millis(2), LossTrace::seeded(0.10, 0xC3));
        let cfg = scfg(0.10 * RATE);
        let mut s = if explicit {
            SenderMachine::with_backend(&cfg, &data, &eps, Backend::Rs, net.now).unwrap()
        } else {
            SenderMachine::new(&cfg, &data, &eps, net.now).unwrap()
        };
        let mut r = ReceiverMachine::new(&rcfg(), net.now);
        run(&mut net, &mut s, &mut r, |_, _| {});
        assert!(!s.is_failed() && !r.is_failed());
        (s.into_report().unwrap(), r.into_report().unwrap())
    };
    let (sd, rd) = run_one(false);
    let (se, re) = run_one(true);
    assert_eq!(sd.passes, se.passes, "pass count");
    assert_eq!(sd.fragments_sent, se.fragments_sent, "fragments offered");
    assert_eq!(sd.data_fragments, se.data_fragments, "data fragments");
    assert_eq!(sd.m_history, se.m_history, "adaptation history");
    assert_eq!(rd.fragments_received, re.fragments_received, "fragments delivered");
    assert_eq!(rd.groups_recovered, re.groups_recovered, "RS recoveries");
    assert_eq!(rd.levels, re.levels, "delivered bytes");
    assert_delivered(&rd, &data);
}

#[test]
fn lambda_windows_pair_one_to_one_across_backends() {
    // λ̂ window accounting is shared by the classic and fountain receive
    // paths (repair symbols carry the same seq space as fragments), and
    // LambdaUpdate rides the reliable control path: every window the
    // receiver closes must land at the sender, in order, value-exact —
    // under either backend — at a cadence bounded by duration / T_W.
    let data = vec![payload(31, 400_000)];
    let eps = vec![1e-7];
    let t_w = 0.002;
    let rc = ReceiverConfig { t_w, ..rcfg() };
    for backend in [Backend::Rs, Backend::Fountain] {
        let mut net = Net::new(Duration::from_millis(2), LossTrace::seeded(0.05, 0xD4));
        let mut s =
            SenderMachine::with_backend(&scfg(0.05 * RATE), &data, &eps, backend, net.now)
                .unwrap();
        let mut r = ReceiverMachine::new(&rc, net.now);
        let dur = run(&mut net, &mut s, &mut r, |_, _| {});
        assert!(!s.is_failed(), "{backend:?}: sender failed");
        assert!(!r.is_failed(), "{backend:?}: receiver failed");
        let sr = s.into_report().unwrap();
        let rr = r.into_report().unwrap();
        assert_delivered(&rr, &data);
        assert_eq!(
            sr.lambda_updates, rr.lambda_reports,
            "{backend:?}: emitted λ̂ windows and received updates must pair one-to-one"
        );
        let windows = rr.lambda_reports.len();
        assert!(windows >= 2, "{backend:?}: only {windows} λ windows over {dur:?}");
        let ceiling = (dur.as_secs_f64() / t_w).ceil() as usize + 2;
        assert!(
            windows <= ceiling,
            "{backend:?}: {windows} windows exceed the cadence ceiling {ceiling}"
        );
    }
}
