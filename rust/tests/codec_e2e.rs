//! Acceptance matrix for the `janus::codec` subsystem (ISSUE 4): a
//! GRF-generated f32 volume travels through the `janus::api` facade
//! over a 5%-loss deterministic testkit wire under every `Contract`
//! variant, and the receiver's *reported* achieved ε is checked against
//! the contract — and against the ground truth.

use janus::api::{
    run_pair, CodecConfig, Contract, Dataset, EventLog, TransferEvent, TransferSpec,
};
use janus::model::{optimize_deadline_bitplane, NetParams};
use janus::refactor::{generate, GrfConfig, Volume};
use janus::testkit::{loss_transport_pair, LossTrace};
use std::time::Duration;

const LOSS: f64 = 0.05;
const RATE: f64 = 200_000.0;

fn volume_dataset(seed: u64) -> (Volume, Dataset) {
    let vol = generate(32, &GrfConfig::default(), seed);
    let cfg = CodecConfig { levels: 4, ladder: vec![4e-3, 5e-4, 8e-5], max_planes: 24 };
    let data = Dataset::from_volume(&vol, &cfg).expect("encodable fixture");
    (vol, data)
}

fn spec(contract: Contract, streams: usize, initial_lambda: f64) -> TransferSpec {
    TransferSpec::builder()
        .contract(contract)
        .streams(streams)
        .net(NetParams { t: 0.0005, r: RATE, lambda: 0.0, n: 32, s: 1024 })
        .initial_lambda(initial_lambda)
        .lambda_window(0.25)
        .idle_timeout(Duration::from_secs(5))
        .max_duration(Duration::from_secs(60))
        .build()
        .unwrap()
}

/// The delivered prefix's LevelDecoded events, in delivery order.
fn level_decoded(log: &EventLog) -> Vec<(u8, f64)> {
    log.events
        .iter()
        .filter_map(|e| match e {
            TransferEvent::LevelDecoded { level, achieved_eps } => Some((*level, *achieved_eps)),
            _ => None,
        })
        .collect()
}

fn assert_certified(vol: &Volume, rep: &janus::api::TransferReport) -> f64 {
    let out = rep
        .received
        .decode_volume()
        .expect("codec stream")
        .expect("delivered prefix decodes");
    let true_err = vol.linf_rel_error(&out.volume);
    assert!(
        true_err <= out.achieved_eps + 1e-12,
        "reported ε {} must bound the ground truth {true_err}",
        out.achieved_eps
    );
    out.achieved_eps
}

// --------------------------------------------------------------- Fidelity

#[test]
fn fidelity_over_lossy_wire_certifies_the_contracted_eps() {
    let (vol, data) = volume_dataset(1);
    let contracted = *data.eps.last().unwrap();
    let (st, rt) = loss_transport_pair(1, |_| LossTrace::seeded(LOSS, 101));
    let mut rlog = EventLog::new();
    let rep = run_pair(
        &spec(Contract::Fidelity(contracted), 1, LOSS * RATE),
        st,
        rt,
        &data,
        None,
        Some(&mut rlog),
    )
    .unwrap();

    // Byte-exact per delivered segment (every rung, under Fidelity).
    for (li, (got, want)) in rep.received.levels.iter().zip(&data.levels).enumerate() {
        assert_eq!(got.as_ref().expect("delivered"), want, "rung {li}");
    }
    let achieved = assert_certified(&vol, &rep);
    assert!(achieved <= contracted + 1e-15, "{achieved} > contracted {contracted}");
    assert!((rep.received.achieved_eps - achieved).abs() < 1e-15, "summary agrees");

    // LevelDecoded: one per rung, in level order, ε tightening to the
    // recorded ladder, after every GroupRecovered.
    let lv = level_decoded(&rlog);
    assert_eq!(lv.len(), data.levels.len());
    for (i, (level, eps)) in lv.iter().enumerate() {
        assert_eq!(*level as usize, i, "level order");
        assert!((eps - data.eps[i]).abs() < 1e-15, "recorded ε replayed");
    }
    let first_decode = rlog
        .events
        .iter()
        .position(|e| matches!(e, TransferEvent::LevelDecoded { .. }))
        .unwrap();
    if let Some(last_group) = rlog
        .events
        .iter()
        .rposition(|e| matches!(e, TransferEvent::GroupRecovered { .. }))
    {
        assert!(last_group < first_decode, "decode events follow recovery events");
    }
    let codec = rep.received.codec.as_ref().expect("codec summary");
    assert_eq!(codec.rungs_decoded, data.levels.len());
    assert_eq!(codec.d, 32);
    assert_eq!(codec.lifting_levels, 4);
}

#[test]
fn fidelity_coarse_bound_ships_only_the_needed_rungs() {
    let (vol, data) = volume_dataset(2);
    // ε request satisfied by rung 1 alone (its recorded ε ≤ 4e-3).
    let (st, rt) = loss_transport_pair(1, |_| LossTrace::seeded(LOSS, 55));
    let mut rlog = EventLog::new();
    let rep = run_pair(
        &spec(Contract::Fidelity(4e-3), 1, LOSS * RATE),
        st,
        rt,
        &data,
        None,
        Some(&mut rlog),
    )
    .unwrap();
    assert_eq!(rep.received.levels.len(), 1, "only rung 1 in the manifest");
    assert_eq!(rep.received.levels[0].as_ref().unwrap(), &data.levels[0]);
    let achieved = assert_certified(&vol, &rep);
    assert!((achieved - data.eps[0]).abs() < 1e-15);
    assert_eq!(level_decoded(&rlog).len(), 1);
}

// ------------------------------------------------------------- BestEffort

#[test]
fn best_effort_over_lossy_wire_delivers_the_full_ladder() {
    let (vol, data) = volume_dataset(3);
    let (st, rt) = loss_transport_pair(1, |_| LossTrace::seeded(LOSS, 202));
    let mut rlog = EventLog::new();
    let rep = run_pair(
        &spec(Contract::BestEffort, 1, LOSS * RATE),
        st,
        rt,
        &data,
        None,
        Some(&mut rlog),
    )
    .unwrap();
    for (got, want) in rep.received.levels.iter().zip(&data.levels) {
        assert_eq!(got.as_ref().unwrap(), want);
    }
    let achieved = assert_certified(&vol, &rep);
    assert!((achieved - *data.eps.last().unwrap()).abs() < 1e-15);
    let lv = level_decoded(&rlog);
    assert_eq!(lv.len(), data.levels.len());
    assert!(lv.windows(2).all(|w| w[0].1 > w[1].1), "ε tightens rung by rung");
}

// --------------------------------------------------------------- Deadline

#[test]
fn deadline_sheds_to_the_maximal_plane_prefix() {
    let (vol, data) = volume_dataset(4);
    assert!(data.levels.len() >= 2);
    assert!(
        data.cuts().iter().any(|c| !c.is_empty()),
        "the encoder must expose plane cuts somewhere"
    );

    // Find a boundary rung `ri` (the first excluded one) and a τ
    // strictly below whole-rung-`ri+1` feasibility whose slack (after
    // the whole-level solve spends its parity budget) fits one of rung
    // ri's plane cuts — probing the exact solver the engine runs. Scan
    // from the largest candidates down: maximal slack buys generous
    // parity for the full rungs and wall-clock headroom.
    let net = NetParams { t: 0.0005, r: 2_000.0, lambda: 0.0, n: 32, s: 1024 };
    let initial_lambda = LOSS * net.r;
    let sched = data.schedule();
    let p = NetParams { lambda: initial_lambda, ..net };
    let steps = 200;
    let mut found = None;
    'boundary: for ri in (1..data.levels.len()).rev() {
        if data.cuts()[ri].is_empty() {
            continue;
        }
        let m_lo = vec![0usize; ri];
        let m_hi = vec![0usize; ri + 1];
        let t_lo = janus::model::transmission_time(&p, &sched, &m_lo);
        let t_hi = janus::model::transmission_time(&p, &sched, &m_hi);
        for i in (0..steps).rev() {
            let tau = t_lo + (t_hi - t_lo) * (i as f64 + 0.5) / steps as f64;
            if let Some(plan) = optimize_deadline_bitplane(&p, &sched, tau) {
                if plan.base.levels == ri && plan.partial.is_some() {
                    found = Some((ri, tau, plan));
                    break 'boundary;
                }
            }
        }
    }
    let (ri, tau, plan) = found.expect("some τ admits a plane-prefix shed");
    let (plevel, cut) = plan.partial.expect("selected for a partial");
    assert_eq!(plevel, ri);
    // Maximality for this τ: the chosen cut fits the slack budget and
    // no larger cut does.
    let slack = tau - plan.base.time;
    let budget_bytes = (slack * p.r).floor() as u64 * p.s as u64;
    assert!(cut.bytes <= budget_bytes, "chosen cut must fit the slack");
    let cuts_r = &data.cuts()[ri];
    let idx = cuts_r.iter().position(|c| *c == cut).expect("cut from the schedule");
    for bigger in &cuts_r[idx + 1..] {
        assert!(
            bigger.bytes > budget_bytes,
            "a larger cut ({} B) would fit the {budget_bytes} B budget — not maximal",
            bigger.bytes
        );
    }

    let build_spec = || {
        TransferSpec::builder()
            .contract(Contract::Deadline(tau))
            .streams(1)
            .net(net)
            .initial_lambda(initial_lambda)
            .lambda_window(0.25)
            .idle_timeout(Duration::from_secs(5))
            .max_duration(Duration::from_secs(60))
            .build()
            .unwrap()
    };
    let mut expected: Vec<&[u8]> = data.levels[..ri].iter().map(|l| l.as_slice()).collect();
    expected.push(&data.levels[ri][..cut.bytes as usize]);
    let mut expect_eps: Vec<f64> = data.eps[..ri].to_vec();
    expect_eps.push(cut.eps);

    // --- 5%-loss wire: delivery depends on the parity the plan bought,
    // but the manifest commitment and any recovered prefix are exact.
    let (st, rt) = loss_transport_pair(1, |_| LossTrace::seeded(LOSS, 404));
    let mut rlog = EventLog::new();
    let rep = run_pair(&build_spec(), st, rt, &data, None, Some(&mut rlog)).unwrap();
    assert_eq!(
        rep.received.levels.len(),
        ri + 1,
        "manifest: {ri} full rungs + the partial"
    );
    assert_eq!(rep.sent.passes, 0, "deadline never retransmits");
    for li in 0..rep.received.levels_recovered {
        assert_eq!(
            rep.received.levels[li].as_ref().unwrap().as_slice(),
            expected[li],
            "rung {li} must be byte-exact"
        );
    }
    if rep.received.levels_recovered > 0 {
        let want = expect_eps[rep.received.levels_recovered - 1];
        assert!(
            (rep.received.achieved_eps - want).abs() < 1e-15,
            "achieved {} vs {want}",
            rep.received.achieved_eps
        );
        let achieved = assert_certified(&vol, &rep);
        assert!((achieved - want).abs() < 1e-15, "decoder certifies the same ε");
        let lv = level_decoded(&rlog);
        assert_eq!(lv.len(), rep.received.levels_recovered, "one decode event per rung");
        for (i, (level, _)) in lv.iter().enumerate() {
            assert_eq!(*level as usize, i);
        }
    }

    // --- Lossless wire, same plan: the planned shed arrives in full —
    // the delivered plane prefix IS the maximal one for this deadline.
    let (st, rt) = loss_transport_pair(1, |_| LossTrace::None);
    let mut rlog = EventLog::new();
    let rep = run_pair(&build_spec(), st, rt, &data, None, Some(&mut rlog)).unwrap();
    assert!(rep.received.levels_recovered >= ri, "full rungs arrive losslessly");
    for li in 0..rep.received.levels_recovered {
        assert_eq!(rep.received.levels[li].as_ref().unwrap().as_slice(), expected[li]);
    }
    if rep.received.levels_recovered == ri + 1 {
        assert!((rep.received.achieved_eps - cut.eps).abs() < 1e-15);
        let achieved = assert_certified(&vol, &rep);
        assert!((achieved - cut.eps).abs() < 1e-15, "cut ε certified end to end");
    }
    let lv = level_decoded(&rlog);
    assert_eq!(lv.len(), rep.received.levels_recovered);
    assert!(lv.windows(2).all(|w| w[0].0 + 1 == w[1].0), "level order");
}

#[test]
fn pooled_deadline_sheds_to_plane_prefix_and_certifies() {
    // The tentpole end-to-end: Deadline on 4 streams with a codec
    // dataset. Probe the exact solver the pooled engine runs (against
    // the aggregate rate N·r) for a τ whose pass-0 plan keeps `ri` full
    // rungs plus a plane-cut prefix of rung `ri`; over a lossless wire
    // the advertised cut arrives in full, the virtual clock stays
    // inside τ, and the decoder certifies the cut's measured ε.
    // Seed 4: the same fixture the single-stream boundary test proves
    // exposes plane cuts.
    let (vol, data) = volume_dataset(4);
    assert!(data.cuts().iter().any(|c| !c.is_empty()));
    let streams = 4usize;
    let net = NetParams { t: 0.0005, r: 2_000.0, lambda: 0.0, n: 32, s: 1024 };
    let agg = NetParams { r: net.r * streams as f64, ..net };
    let sched = data.schedule();
    let steps = 200;
    let mut found = None;
    'boundary: for ri in (1..data.levels.len()).rev() {
        if data.cuts()[ri].is_empty() {
            continue;
        }
        let m_lo = vec![0usize; ri];
        let m_hi = vec![0usize; ri + 1];
        let t_lo = janus::model::transmission_time(&agg, &sched, &m_lo);
        let t_hi = janus::model::transmission_time(&agg, &sched, &m_hi);
        for i in (0..steps).rev() {
            let tau = t_lo + (t_hi - t_lo) * (i as f64 + 0.5) / steps as f64;
            if let Some(plan) = optimize_deadline_bitplane(&agg, &sched, tau) {
                if plan.base.levels == ri && plan.partial.is_some() {
                    found = Some((ri, tau, plan));
                    break 'boundary;
                }
            }
        }
    }
    let (ri, tau, plan) = found.expect("some τ admits a plane-prefix shed");
    let (plevel, cut) = plan.partial.expect("selected for a partial");
    assert_eq!(plevel, ri);

    let spec = TransferSpec::builder()
        .contract(Contract::Deadline(tau))
        .streams(streams)
        .net(net)
        .initial_lambda(0.0)
        .lambda_window(0.25)
        .idle_timeout(Duration::from_secs(5))
        .max_duration(Duration::from_secs(60))
        .build()
        .expect("pooled deadline spec builds — the restriction is gone");
    let (st, rt) = loss_transport_pair(streams, |_| LossTrace::None);
    let mut rlog = EventLog::new();
    let rep = run_pair(&spec, st, rt, &data, None, Some(&mut rlog)).unwrap();

    assert!(rep.sent.pooled().is_some(), "streams=4 routes pooled");
    assert_eq!(
        rep.received.levels.len(),
        ri + 1,
        "manifest: {ri} full rungs + the plane-cut partial"
    );
    assert_eq!(rep.received.levels_recovered, ri + 1, "lossless wire delivers the plan");
    for li in 0..ri {
        assert_eq!(rep.received.levels[li].as_ref().unwrap(), &data.levels[li]);
    }
    assert_eq!(
        rep.received.levels[ri].as_ref().unwrap().as_slice(),
        &data.levels[ri][..cut.bytes as usize],
        "the partial rung is the advertised byte prefix"
    );
    let dl = rep.sent.deadline().expect("pooled deadline outcome");
    // τ was scanned to sit exactly at a plan boundary; `met` already
    // absorbs the whole-group ceil rounding of Eq. 12's fractional
    // pricing, so a respected lossless plan reports met.
    assert!(dl.met, "lossless run within the plan: {dl:?} vs τ={tau}");
    let rounding = (data.levels.len() as f64 + 2.0) / agg.r;
    assert!(
        dl.virtual_elapsed <= tau + rounding,
        "virtual clock within the plan (+rounding): {dl:?} vs τ={tau}"
    );
    assert!((dl.planned_eps - cut.eps).abs() < 1e-15, "plan promises the cut ε");
    assert!((dl.advertised_eps - cut.eps).abs() < 1e-15, "nothing shed beyond the plan");
    assert!((rep.received.achieved_eps - cut.eps).abs() < 1e-15);
    // The progressive decoder certifies the same ε against ground truth.
    let achieved = assert_certified(&vol, &rep);
    assert!((achieved - cut.eps).abs() < 1e-15, "cut ε certified end to end");
    let lv = level_decoded(&rlog);
    assert_eq!(lv.len(), ri + 1, "one decode event per delivered rung");
    assert!((lv[ri].1 - cut.eps).abs() < 1e-15);
}

#[test]
fn residual_replan_prices_whole_groups_not_fractions() {
    // The Deadline re-plan prices retransmission passes with
    // `ResidualSchedule::transmission_time`: every pending group resends
    // ceil'd data fragments plus `m_j` parity *per pending group*. The
    // fractional Eq. 9 both undercharges (sub-fragment tails) and
    // mischarges parity (G·m is not `n/(n−m)` byte inflation).
    let (_, data) = volume_dataset(6);
    let sched = data.schedule();
    let net = NetParams { t: 0.0005, r: 2_000.0, lambda: 0.0, n: 32, s: 1024 };
    let k0 = net.n - 4; // the frozen pass-0 data geometry
    let groups: Vec<u64> = sched
        .sizes
        .iter()
        .map(|&sz| sz.div_ceil(k0 as u64 * net.s as u64))
        .collect();
    let residual = janus::model::ResidualSchedule::new(data.schedule(), groups.clone());
    let l = sched.num_levels();

    // Parity-free: the exact price is the ceil'd fragment walk, never
    // below the fractional byte volume.
    let exact0 = residual.transmission_time(&net, &vec![0; l]);
    let frac0 = janus::model::transmission_time(&net, &sched, &vec![0; l]);
    assert!(
        exact0 >= frac0 - 1e-12,
        "ceil pricing cannot undercut the fractional volume: {exact0} < {frac0}"
    );

    // Adding parity costs exactly G_j fragments per unit of m_j — the
    // per-group k+m accounting the re-plan budget debits.
    let m = vec![3usize; l];
    let exact_m = residual.transmission_time(&net, &m);
    let parity_frags: f64 = groups.iter().map(|&g| g as f64 * 3.0).sum();
    assert!(
        (exact_m - exact0 - parity_frags / net.r).abs() < 1e-9,
        "parity must be priced per pending group: {} vs {}",
        exact_m - exact0,
        parity_frags / net.r
    );

    // A spent budget (e.g. the unreported-tail debit when the lost list
    // overflowed the wire message) admits no plan at all.
    assert!(
        janus::model::BitplaneDeadlinePlan::replan_residual_exact(&net, &residual, 0.0, 1.0)
            .is_none(),
        "zero/negative budget must not produce a plan"
    );
}

#[test]
fn pooled_deadline_replans_under_loss_and_respects_tau() {
    // Satellite: the pass-barrier re-plan prices the residual with the
    // exact per-group schedule, so a τ with honest headroom is met on
    // the virtual clock even when 5% loss forces retransmission passes,
    // and the final advertisement is exactly what the receiver decodes.
    let (vol, data) = volume_dataset(7);
    let streams = 4usize;
    let net = NetParams { t: 0.0005, r: 2_000.0, lambda: 0.0, n: 32, s: 1024 };
    let agg = NetParams { r: net.r * streams as f64, ..net };
    let sched = data.schedule();
    let l = sched.num_levels();
    let t_all = janus::model::transmission_time(&agg, &sched, &vec![0; l]);
    let tau = 2.2 * t_all; // real retransmission headroom past pass 0

    let spec = TransferSpec::builder()
        .contract(Contract::Deadline(tau))
        .streams(streams)
        .net(net)
        .initial_lambda(LOSS * net.r * streams as f64)
        .lambda_window(0.25)
        .idle_timeout(Duration::from_secs(5))
        .max_duration(Duration::from_secs(60))
        .build()
        .unwrap();
    let (st, rt) = loss_transport_pair(streams, |w| LossTrace::seeded(LOSS, 700 + w as u64));
    let rep = run_pair(&spec, st, rt, &data, None, None).unwrap();

    let dl = rep.sent.deadline().expect("pooled deadline outcome");
    let rounding = (l as f64 + 2.0) / agg.r;
    assert!(
        dl.virtual_elapsed <= tau + rounding,
        "exact residual pricing keeps the virtual clock inside τ: {dl:?} vs τ={tau}"
    );
    assert!(dl.met, "honest headroom + exact pricing meets the deadline: {dl:?}");
    // The advertisement is honored: every advertised rung arrives and
    // the decoder certifies the advertised ε against ground truth.
    assert_eq!(
        rep.received.levels_recovered,
        rep.received.levels.len(),
        "all advertised rungs delivered"
    );
    assert!(
        (rep.received.achieved_eps - dl.advertised_eps).abs() < 1e-15,
        "delivered ε {} vs advertised {}",
        rep.received.achieved_eps,
        dl.advertised_eps
    );
    let achieved = assert_certified(&vol, &rep);
    assert!((achieved - dl.advertised_eps).abs() < 1e-15);
}

// ----------------------------------------------------------------- Pooled

#[test]
fn pooled_fidelity_certifies_over_asymmetric_loss() {
    let (vol, data) = volume_dataset(5);
    let contracted = *data.eps.last().unwrap();
    let streams = 4usize;
    let (st, rt) =
        loss_transport_pair(streams, |w| LossTrace::seeded(LOSS, 500 + w as u64));
    let mut rlog = EventLog::new();
    let rep = run_pair(
        &spec(Contract::Fidelity(contracted), streams, LOSS * RATE * streams as f64),
        st,
        rt,
        &data,
        None,
        Some(&mut rlog),
    )
    .unwrap();
    for (got, want) in rep.received.levels.iter().zip(&data.levels) {
        assert_eq!(got.as_ref().unwrap(), want);
    }
    let achieved = assert_certified(&vol, &rep);
    assert!(achieved <= contracted + 1e-15);
    assert_eq!(level_decoded(&rlog).len(), data.levels.len());
    assert!(rep.sent.pooled().is_some(), "streams=4 routes pooled");
}

// ------------------------------------------------------- Segment order

#[test]
fn marginal_segment_order_never_worsens_certified_eps_at_equal_budget() {
    use janus::codec::{encode_ordered, Decoder, Encoded, SegmentOrder};
    let vol = generate(32, &GrfConfig::default(), 0x06D3);
    let cfg = CodecConfig { levels: 4, ladder: vec![4e-3, 5e-4, 8e-5], max_planes: 24 };
    let lvl = encode_ordered(&vol, &cfg, SegmentOrder::LevelOrder).unwrap();
    let marg = encode_ordered(&vol, &cfg, SegmentOrder::MarginalEps).unwrap();
    // A rung's plane plan — and thus its full-rung measured ε and byte
    // count — is fixed before scheduling; only interior boundaries move.
    assert_eq!(lvl.eps, marg.eps);
    assert_eq!(lvl.planes, marg.planes);
    for r in 0..lvl.rungs.len() {
        assert_eq!(lvl.rungs[r].len(), marg.rungs[r].len(), "rung {r} total bytes");
        let start = if r == 0 { 1.0 } else { lvl.eps[r - 1] };
        // Certified ε at a byte budget mid-rung: the best PlaneCut shed
        // point inside the budget (the Deadline contract's semantics).
        let certified = |enc: &Encoded, budget: u64| -> f64 {
            let mut e = start;
            for cut in &enc.cuts[r] {
                if cut.bytes <= budget && cut.eps < e {
                    e = cut.eps;
                }
            }
            if budget >= enc.rungs[r].len() as u64 {
                e = e.min(enc.eps[r]);
            }
            e
        };
        let budgets: Vec<u64> = lvl.cuts[r]
            .iter()
            .chain(&marg.cuts[r])
            .map(|c| c.bytes)
            .chain([lvl.rungs[r].len() as u64])
            .collect();
        for &budget in &budgets {
            let (m, l2) = (certified(&marg, budget), certified(&lvl, budget));
            assert!(
                m <= l2 + 1e-15,
                "rung {r} @ {budget}B: marginal certifies {m}, level order {l2}"
            );
        }
    }
    // Both orders decode byte-exactly to the same full-precision output.
    let refs_l: Vec<&[u8]> = lvl.rungs.iter().map(|r| r.as_slice()).collect();
    let refs_m: Vec<&[u8]> = marg.rungs.iter().map(|r| r.as_slice()).collect();
    let out_l = Decoder::decode(&refs_l).unwrap();
    let out_m = Decoder::decode(&refs_m).unwrap();
    assert_eq!(out_l.volume.data, out_m.volume.data, "segment order is decode-invariant");
    assert!((out_l.achieved_eps - out_m.achieved_eps).abs() < 1e-18);
    assert_eq!(out_l.planes_used, out_m.planes_used);
}
