//! Failure injection against the real engines through the `janus::api`
//! facade: corrupted datagrams, reordering, silent peers, heavy loss,
//! and contract edges.

use janus::api::{run_pair, ChannelTransport, Contract, Dataset, Endpoint, TransferSpec};
use janus::coordinator::Packet;
use janus::model::params::NetParams;
use janus::transport::channel::{mem_pair, Datagram, LossyChannel, MemChannel, ReorderChannel};
use janus::util::Pcg64;
use std::time::Duration;

fn test_dataset(seed: u64) -> Dataset {
    let mut rng = Pcg64::seeded(seed);
    let sizes = [30_000usize, 120_000, 240_000, 700_000];
    let eps = vec![0.004, 0.0005, 0.00006, 0.0000001];
    Dataset::new(
        sizes
            .iter()
            .map(|&sz| {
                let mut v = vec![0u8; sz];
                rng.fill_bytes(&mut v);
                v
            })
            .collect(),
        eps,
    )
    .unwrap()
}

fn net() -> NetParams {
    NetParams { t: 0.0005, r: 200_000.0, lambda: 0.0, n: 32, s: 1024 }
}

fn spec(contract: Contract, initial_lambda: f64) -> TransferSpec {
    TransferSpec::builder()
        .contract(contract)
        .net(net())
        .initial_lambda(initial_lambda)
        .lambda_window(0.05)
        .idle_timeout(Duration::from_secs(3))
        .max_duration(Duration::from_secs(30))
        .build()
        .unwrap()
}

/// Channel wrapper that flips a bit in a fraction of outgoing datagrams
/// (CRC must catch these — they count as losses, not corruption).
struct CorruptingChannel<C: Datagram> {
    inner: C,
    rng: Pcg64,
    fraction: f64,
}

impl<C: Datagram> Datagram for CorruptingChannel<C> {
    fn send(&mut self, buf: &[u8]) {
        if self.rng.bool_with(self.fraction) && buf.len() > 10 {
            let mut copy = buf.to_vec();
            let idx = self.rng.range(0, copy.len());
            copy[idx] ^= 0x10;
            self.inner.send(&copy);
        } else {
            self.inner.send(buf);
        }
    }
    fn recv_into(&mut self, buf: &mut [u8], timeout: Duration) -> Option<usize> {
        self.inner.recv_into(buf, timeout)
    }
    fn try_recv_into(&mut self, buf: &mut [u8]) -> Option<usize> {
        self.inner.try_recv_into(buf)
    }
}

#[test]
fn corrupted_fragments_are_recovered_via_crc_and_parity() {
    let data = test_dataset(1);
    let (a, b) = mem_pair();
    let corrupting = CorruptingChannel { inner: a, rng: Pcg64::seeded(5), fraction: 0.02 };
    let s = spec(Contract::Fidelity(1e-7), 0.02 * net().r);
    let rep = run_pair(
        &s,
        ChannelTransport::new(corrupting),
        ChannelTransport::new(b),
        &data,
        None,
        None,
    )
    .unwrap();
    assert_eq!(rep.received.levels_recovered, 4, "corruption must be transparent");
    for (got, want) in rep.received.levels.iter().zip(&data.levels) {
        assert_eq!(got.as_ref().unwrap(), want);
    }
}

#[test]
fn reordered_fragments_are_reassembled() {
    let data = test_dataset(2);
    let (a, b) = mem_pair();
    let reorder = ReorderChannel::new(a, 64, 9);
    let s = spec(Contract::Fidelity(1e-7), 0.0);
    let rep = run_pair(
        &s,
        ChannelTransport::new(reorder),
        ChannelTransport::new(b),
        &data,
        None,
        None,
    )
    .unwrap();
    assert_eq!(rep.received.levels_recovered, 4);
    for (got, want) in rep.received.levels.iter().zip(&data.levels) {
        assert_eq!(got.as_ref().unwrap(), want);
    }
}

#[test]
fn heavy_loss_still_delivers_error_bound_contract() {
    // 15% loss — way past any reasonable WAN; Alg. 1 must converge via
    // parity + repeated passive retransmission.
    let data = test_dataset(3);
    let (a, b) = mem_pair();
    let lossy = LossyChannel::new(a, 0.15, 21);
    let s = spec(Contract::Fidelity(1e-7), 0.15 * net().r);
    let rep = run_pair(
        &s,
        ChannelTransport::new(lossy),
        ChannelTransport::new(b),
        &data,
        None,
        None,
    )
    .unwrap();
    assert_eq!(rep.received.levels_recovered, 4);
    assert!(rep.sent.passes >= 1 || rep.received.groups_recovered > 0);
    for (got, want) in rep.received.levels.iter().zip(&data.levels) {
        assert_eq!(got.as_ref().unwrap(), want);
    }
}

#[test]
fn receiver_times_out_when_sender_never_appears() {
    let (_a, b): (MemChannel, MemChannel) = mem_pair();
    let s = TransferSpec::builder()
        .lambda_window(0.05)
        .idle_timeout(Duration::from_millis(200))
        .max_duration(Duration::from_secs(2))
        .build()
        .unwrap();
    let err = Endpoint::new(s)
        .receive(&mut ChannelTransport::new(b), None)
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("manifest"), "unexpected error: {msg}");
}

#[test]
fn sender_fails_cleanly_when_receiver_never_acks() {
    let (a, _b) = mem_pair();
    let data = test_dataset(4);
    let s = spec(Contract::Fidelity(1e-7), 0.0);
    let err = Endpoint::new(s)
        .send(&mut ChannelTransport::new(a), &data, None)
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("acknowledge"), "unexpected error: {msg}");
}

#[test]
fn sender_rejects_unachievable_error_bound() {
    let (a, _b) = mem_pair();
    let data = test_dataset(5);
    let s = spec(Contract::Fidelity(1e-12), 0.0); // below ε_4
    let err = Endpoint::new(s)
        .send(&mut ChannelTransport::new(a), &data, None)
        .unwrap_err();
    assert!(format!("{err:#}").contains("unachievable"));
}

#[test]
fn sender_rejects_impossible_deadline() {
    let (a, _b) = mem_pair();
    let data = test_dataset(6);
    let s = spec(Contract::Deadline(1e-9), 0.0);
    let err = Endpoint::new(s)
        .send(&mut ChannelTransport::new(a), &data, None)
        .unwrap_err();
    assert!(format!("{err:#}").contains("infeasible"));
}

#[test]
fn garbage_datagrams_are_ignored() {
    // Blast random bytes at a receiver alongside a real transfer.
    let data = test_dataset(7);
    let (a, b) = mem_pair();

    struct GarbageInjector<C: Datagram> {
        inner: C,
        rng: Pcg64,
    }
    impl<C: Datagram> Datagram for GarbageInjector<C> {
        fn send(&mut self, buf: &[u8]) {
            if self.rng.bool_with(0.05) {
                let mut junk = vec![0u8; self.rng.range(1, 64)];
                self.rng.fill_bytes(&mut junk);
                self.inner.send(&junk);
            }
            self.inner.send(buf);
        }
        fn recv_into(&mut self, buf: &mut [u8], timeout: Duration) -> Option<usize> {
            self.inner.recv_into(buf, timeout)
        }
        fn try_recv_into(&mut self, buf: &mut [u8]) -> Option<usize> {
            self.inner.try_recv_into(buf)
        }
    }

    let inj = GarbageInjector { inner: a, rng: Pcg64::seeded(13) };
    let s = spec(Contract::Fidelity(1e-7), 0.0);
    let rep = run_pair(
        &s,
        ChannelTransport::new(inj),
        ChannelTransport::new(b),
        &data,
        None,
        None,
    )
    .unwrap();
    assert_eq!(rep.received.levels_recovered, 4);
}

#[test]
fn wire_format_fuzz_never_panics() {
    // Random byte soup into the packet decoder: errors allowed, panics not.
    let mut rng = Pcg64::seeded(99);
    for _ in 0..20_000 {
        let len = rng.range(0, 256);
        let mut buf = vec![0u8; len];
        rng.fill_bytes(&mut buf);
        let _ = Packet::decode(&buf);
    }
    // Truncations of a valid packet.
    let valid = Packet::Fragment(
        janus::coordinator::FragmentHeader {
            level: 1,
            stream: 0,
            ftg: 7,
            index: 3,
            k: 28,
            m: 4,
            seq: 42,
            pass: 0,
        },
        vec![0xAB; 512],
    )
    .encode();
    for cut in 0..valid.len() {
        let _ = Packet::decode(&valid[..cut]);
    }
}
