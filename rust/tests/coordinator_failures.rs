//! Failure injection against the real coordinator engines: corrupted
//! datagrams, reordering, silent peers, heavy loss, and contract edges.

use janus::coordinator::{
    run_receiver, run_sender, run_session, Contract, Packet, ReceiverConfig, SenderConfig,
};
use janus::model::params::NetParams;
use janus::transport::channel::{mem_pair, Datagram, LossyChannel, MemChannel, ReorderChannel};
use janus::util::Pcg64;
use std::time::Duration;

fn test_levels(seed: u64) -> (Vec<Vec<u8>>, Vec<f64>) {
    let mut rng = Pcg64::seeded(seed);
    let sizes = [30_000usize, 120_000, 240_000, 700_000];
    let eps = vec![0.004, 0.0005, 0.00006, 0.0000001];
    (
        sizes
            .iter()
            .map(|&sz| {
                let mut v = vec![0u8; sz];
                rng.fill_bytes(&mut v);
                v
            })
            .collect(),
        eps,
    )
}

fn net() -> NetParams {
    NetParams { t: 0.0005, r: 200_000.0, lambda: 0.0, n: 32, s: 1024 }
}

fn sender_cfg(contract: Contract) -> SenderConfig {
    SenderConfig {
        net: net(),
        contract,
        initial_lambda: 0.0,
        max_duration: Duration::from_secs(30),
    }
}

fn receiver_cfg() -> ReceiverConfig {
    ReceiverConfig {
        t_w: 0.05,
        idle_timeout: Duration::from_secs(3),
        max_duration: Duration::from_secs(30),
    }
}

/// Channel wrapper that flips a bit in a fraction of outgoing datagrams
/// (CRC must catch these — they count as losses, not corruption).
struct CorruptingChannel<C: Datagram> {
    inner: C,
    rng: Pcg64,
    fraction: f64,
}

impl<C: Datagram> Datagram for CorruptingChannel<C> {
    fn send(&mut self, buf: &[u8]) {
        if self.rng.bool_with(self.fraction) && buf.len() > 10 {
            let mut copy = buf.to_vec();
            let idx = self.rng.range(0, copy.len());
            copy[idx] ^= 0x10;
            self.inner.send(&copy);
        } else {
            self.inner.send(buf);
        }
    }
    fn recv_timeout(&mut self, timeout: Duration) -> Option<Vec<u8>> {
        self.inner.recv_timeout(timeout)
    }
    fn try_recv(&mut self) -> Option<Vec<u8>> {
        self.inner.try_recv()
    }
}

#[test]
fn corrupted_fragments_are_recovered_via_crc_and_parity() {
    let (levels, eps) = test_levels(1);
    let (a, b) = mem_pair();
    let corrupting = CorruptingChannel { inner: a, rng: Pcg64::seeded(5), fraction: 0.02 };
    let mut cfg = sender_cfg(Contract::ErrorBound(1e-7));
    cfg.initial_lambda = 0.02 * cfg.net.r;
    let (_, r) = run_session(corrupting, b, cfg, receiver_cfg(), levels.clone(), eps).unwrap();
    assert_eq!(r.levels_recovered, 4, "corruption must be transparent");
    for (got, want) in r.levels.iter().zip(&levels) {
        assert_eq!(got.as_ref().unwrap(), want);
    }
}

#[test]
fn reordered_fragments_are_reassembled() {
    let (levels, eps) = test_levels(2);
    let (a, b) = mem_pair();
    let reorder = ReorderChannel::new(a, 64, 9);
    let cfg = sender_cfg(Contract::ErrorBound(1e-7));
    let (_, r) = run_session(reorder, b, cfg, receiver_cfg(), levels.clone(), eps).unwrap();
    assert_eq!(r.levels_recovered, 4);
    for (got, want) in r.levels.iter().zip(&levels) {
        assert_eq!(got.as_ref().unwrap(), want);
    }
}

#[test]
fn heavy_loss_still_delivers_error_bound_contract() {
    // 15% loss — way past any reasonable WAN; Alg. 1 must converge via
    // parity + repeated passive retransmission.
    let (levels, eps) = test_levels(3);
    let (a, b) = mem_pair();
    let lossy = LossyChannel::new(a, 0.15, 21);
    let mut cfg = sender_cfg(Contract::ErrorBound(1e-7));
    cfg.initial_lambda = 0.15 * cfg.net.r;
    let (s, r) = run_session(lossy, b, cfg, receiver_cfg(), levels.clone(), eps).unwrap();
    assert_eq!(r.levels_recovered, 4);
    assert!(s.passes >= 1 || r.groups_recovered > 0);
    for (got, want) in r.levels.iter().zip(&levels) {
        assert_eq!(got.as_ref().unwrap(), want);
    }
}

#[test]
fn receiver_times_out_when_sender_never_appears() {
    let (_a, mut b): (MemChannel, MemChannel) = mem_pair();
    let cfg = ReceiverConfig {
        t_w: 0.05,
        idle_timeout: Duration::from_millis(200),
        max_duration: Duration::from_secs(2),
    };
    let err = run_receiver(&mut b, &cfg).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("manifest"), "unexpected error: {msg}");
}

#[test]
fn sender_fails_cleanly_when_receiver_never_acks() {
    let (mut a, _b) = mem_pair();
    let (levels, eps) = test_levels(4);
    let cfg = sender_cfg(Contract::ErrorBound(1e-7));
    let err = run_sender(&mut a, &cfg, &levels, &eps).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("acknowledge"), "unexpected error: {msg}");
}

#[test]
fn sender_rejects_unachievable_error_bound() {
    let (mut a, _b) = mem_pair();
    let (levels, eps) = test_levels(5);
    let cfg = sender_cfg(Contract::ErrorBound(1e-12)); // below ε_4
    let err = run_sender(&mut a, &cfg, &levels, &eps).unwrap_err();
    assert!(format!("{err:#}").contains("unachievable"));
}

#[test]
fn sender_rejects_impossible_deadline() {
    let (mut a, _b) = mem_pair();
    let (levels, eps) = test_levels(6);
    let cfg = sender_cfg(Contract::Deadline(1e-9));
    let err = run_sender(&mut a, &cfg, &levels, &eps).unwrap_err();
    assert!(format!("{err:#}").contains("infeasible"));
}

#[test]
fn garbage_datagrams_are_ignored() {
    // Blast random bytes at a receiver alongside a real transfer.
    let (levels, eps) = test_levels(7);
    let (a, b) = mem_pair();

    struct GarbageInjector<C: Datagram> {
        inner: C,
        rng: Pcg64,
    }
    impl<C: Datagram> Datagram for GarbageInjector<C> {
        fn send(&mut self, buf: &[u8]) {
            if self.rng.bool_with(0.05) {
                let mut junk = vec![0u8; self.rng.range(1, 64)];
                self.rng.fill_bytes(&mut junk);
                self.inner.send(&junk);
            }
            self.inner.send(buf);
        }
        fn recv_timeout(&mut self, timeout: Duration) -> Option<Vec<u8>> {
            self.inner.recv_timeout(timeout)
        }
        fn try_recv(&mut self) -> Option<Vec<u8>> {
            self.inner.try_recv()
        }
    }

    let inj = GarbageInjector { inner: a, rng: Pcg64::seeded(13) };
    let cfg = sender_cfg(Contract::ErrorBound(1e-7));
    let (_, r) = run_session(inj, b, cfg, receiver_cfg(), levels.clone(), eps).unwrap();
    assert_eq!(r.levels_recovered, 4);
}

#[test]
fn wire_format_fuzz_never_panics() {
    // Random byte soup into the packet decoder: errors allowed, panics not.
    let mut rng = Pcg64::seeded(99);
    for _ in 0..20_000 {
        let len = rng.range(0, 256);
        let mut buf = vec![0u8; len];
        rng.fill_bytes(&mut buf);
        let _ = Packet::decode(&buf);
    }
    // Truncations of a valid packet.
    let valid = Packet::Fragment(
        janus::coordinator::FragmentHeader {
            level: 1,
            stream: 0,
            ftg: 7,
            index: 3,
            k: 28,
            m: 4,
            seq: 42,
            pass: 0,
        },
        vec![0xAB; 512],
    )
    .encode();
    for cut in 0..valid.len() {
        let _ = Packet::decode(&valid[..cut]);
    }
}
