//! Wire-format invariants: randomized packet roundtrips and malformed
//! input (truncation, corruption, garbage) that must produce errors —
//! never panics, never silently-wrong packets.

use janus::coordinator::packet::{encode_fragment_into, is_fragment};
use janus::coordinator::{FragmentHeader, Manifest, ManifestLevel, Packet, RepairHeader};
use janus::util::prop::{check, no_shrink, PropConfig};
use janus::util::Pcg64;

fn random_fragment(rng: &mut Pcg64) -> Packet {
    let len = rng.range(0, 4097);
    let mut payload = vec![0u8; len];
    rng.fill_bytes(&mut payload);
    Packet::Fragment(
        FragmentHeader {
            level: rng.next_below(8) as u8,
            stream: rng.next_below(256) as u8,
            ftg: rng.next_u64() as u32,
            index: rng.next_below(256) as u8,
            k: rng.next_below(256) as u8,
            m: rng.next_below(256) as u8,
            seq: rng.next_u64(),
            pass: rng.next_u64() as u32,
        },
        payload,
    )
}

fn random_repair(rng: &mut Pcg64) -> Packet {
    let len = rng.range(0, 4097);
    let mut payload = vec![0u8; len];
    rng.fill_bytes(&mut payload);
    Packet::RepairSymbol(
        RepairHeader {
            group: rng.next_u64() as u32,
            esi: rng.next_u64() as u32,
            seed: rng.next_u64(),
            seq: rng.next_u64(),
        },
        payload,
    )
}

fn random_packet(rng: &mut Pcg64) -> Packet {
    match rng.next_below(12) {
        0 => random_fragment(rng),
        1 => Packet::LambdaUpdate { lambda: rng.next_f64() * 1e6 },
        2 => Packet::EndOfPass { pass: rng.next_u64() as u32 },
        3 => {
            let count = rng.range(0, 64);
            let ftgs: Vec<(u8, u32)> = (0..count)
                .map(|_| (rng.next_below(8) as u8, rng.next_u64() as u32))
                .collect();
            // `total` may exceed the carried list (truncation marker).
            let total = ftgs.len() as u32 + rng.next_below(1000) as u32;
            Packet::LostList { pass: rng.next_u64() as u32, total, ftgs }
        }
        4 => Packet::Done,
        5 => {
            let count = rng.range(0, 8);
            Packet::Manifest(Manifest {
                n: rng.next_below(256) as u8,
                s: rng.next_u64() as u32,
                streams: rng.next_below(256) as u8,
                contract: rng.next_below(2) as u8,
                levels: (0..count)
                    .map(|_| ManifestLevel {
                        size: rng.next_u64(),
                        eps: rng.next_f64(),
                        m0: rng.next_below(129) as u8,
                        cut: rng.next_below(2) == 1,
                    })
                    .collect(),
            })
        }
        6 => Packet::ManifestAck,
        7 => Packet::StreamEnd {
            stream: rng.next_below(256) as u8,
            pass: rng.next_u64() as u32,
            sent: rng.next_u64(),
        },
        8 => Packet::PassStats {
            pass: rng.next_u64() as u32,
            expected: rng.next_u64(),
            received: rng.next_u64(),
            runs: rng.next_u64() as u32,
            burst_lost: rng.next_u64(),
        },
        9 => Packet::LevelShed {
            level: rng.next_below(256) as u8,
            bytes: rng.next_u64(),
            eps: rng.next_f64(),
        },
        10 => random_repair(rng),
        _ => Packet::GroupAck { upto: rng.next_u64() as u32, bitmap: rng.next_u64() },
    }
}

#[test]
fn prop_every_packet_roundtrips_bit_exact() {
    check(
        &PropConfig { cases: 400, ..Default::default() },
        |rng| rng.next_u64(),
        no_shrink,
        |&seed| {
            let mut rng = Pcg64::seeded(seed);
            let p = random_packet(&mut rng);
            let buf = p.encode();
            match Packet::decode(&buf) {
                Ok(q) if q == p => Ok(()),
                Ok(q) => Err(format!("roundtrip mismatch:\n  sent {p:?}\n  got {q:?}")),
                Err(e) => Err(format!("decode failed on own encoding: {e} ({p:?})")),
            }
        },
    );
}

#[test]
fn prop_truncations_error_not_panic() {
    // Every strict prefix of a valid encoding must decode to Err — the
    // CRC trailer guarantees it — and must never panic.
    check(
        &PropConfig { cases: 60, ..Default::default() },
        |rng| rng.next_u64(),
        no_shrink,
        |&seed| {
            let mut rng = Pcg64::seeded(seed);
            let buf = random_packet(&mut rng).encode();
            for cut in 0..buf.len() {
                if Packet::decode(&buf[..cut]).is_ok() {
                    return Err(format!("prefix of len {cut}/{} decoded Ok", buf.len()));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_single_byte_corruption_detected() {
    check(
        &PropConfig { cases: 60, ..Default::default() },
        |rng| rng.next_u64(),
        no_shrink,
        |&seed| {
            let mut rng = Pcg64::seeded(seed);
            let p = random_packet(&mut rng);
            let mut buf = p.encode();
            let idx = rng.range(0, buf.len());
            let bit = 1u8 << rng.next_below(8);
            buf[idx] ^= bit;
            match Packet::decode(&buf) {
                Err(_) => Ok(()),
                Ok(q) => Err(format!(
                    "flipped bit {bit:#x} at byte {idx} accepted: {q:?}"
                )),
            }
        },
    );
}

#[test]
fn prop_random_garbage_never_panics_and_rarely_validates() {
    check(
        &PropConfig { cases: 300, ..Default::default() },
        |rng| rng.next_u64(),
        no_shrink,
        |&seed| {
            let mut rng = Pcg64::seeded(seed);
            let len = rng.range(0, 512);
            let mut buf = vec![0u8; len];
            rng.fill_bytes(&mut buf);
            // 32-bit CRC: a random buffer passing validation is a
            // ~2^-32 event; treat acceptance as a failure signal.
            match Packet::decode(&buf) {
                Err(_) => Ok(()),
                Ok(p) => Err(format!("garbage of len {len} validated as {p:?}")),
            }
        },
    );
}

#[test]
fn corrupted_length_field_cannot_overread() {
    // Forge a fragment whose declared payload length exceeds the buffer,
    // with a *recomputed* CRC (an attacker-controlled datagram): decode
    // must report truncation, not read out of bounds.
    let h = FragmentHeader {
        level: 0,
        stream: 0,
        ftg: 1,
        index: 0,
        k: 4,
        m: 2,
        seq: 9,
        pass: 0,
    };
    let mut buf = Vec::new();
    encode_fragment_into(&h, &[0xCC; 64], &mut buf);
    // Payload length lives right before the payload: kind(1) + header
    // fields... patch it to a huge value and re-seal the CRC.
    let len_off = 1 + 1 + 1 + 4 + 1 + 1 + 1 + 8 + 4;
    buf.truncate(buf.len() - 4); // drop old CRC
    buf[len_off..len_off + 4].copy_from_slice(&u32::MAX.to_le_bytes());
    let mut h32 = janus::util::crc32::Hasher::new();
    h32.update(&buf);
    let crc = h32.finalize();
    buf.extend_from_slice(&crc.to_le_bytes());
    match Packet::decode(&buf) {
        Err(e) => assert!(format!("{e}").contains("short"), "unexpected error {e}"),
        Ok(p) => panic!("oversized length accepted: {p:?}"),
    }
}

#[test]
fn manifest_carries_contract_and_shed_geometry() {
    // The pooled Deadline tentpole rides on these fields: the contract
    // byte (no longer hardcoded 0), the per-level pass-0 parity m0 the
    // receiver recomputes never-seen FTG strides from, and the plane-cut
    // flag marking a level shed to a decodable prefix.
    let m = Manifest {
        n: 32,
        s: 1024,
        streams: 4,
        contract: 1,
        levels: vec![
            ManifestLevel { size: 123_456, eps: 0.004, m0: 7, cut: false },
            ManifestLevel { size: 40 * 1024, eps: 0.00042, m0: 0, cut: true },
        ],
    };
    let buf = Packet::Manifest(m.clone()).encode();
    match Packet::decode(&buf).unwrap() {
        Packet::Manifest(got) => {
            assert_eq!(got, m);
            assert_eq!(got.contract, 1, "contract byte survives the wire");
            assert_eq!(got.levels[0].m0, 7);
            assert!(!got.levels[0].cut);
            assert_eq!(got.levels[1].m0, 0);
            assert!(got.levels[1].cut, "plane-cut flag survives the wire");
        }
        other => panic!("expected manifest, got {other:?}"),
    }
    // The shed advertisement roundtrips, including the abandon form.
    for p in [
        Packet::LevelShed { level: 2, bytes: 40 * 1024, eps: 0.00042 },
        Packet::LevelShed { level: 0, bytes: 0, eps: 1.0 },
    ] {
        let buf = p.encode();
        assert_eq!(Packet::decode(&buf).unwrap(), p);
        assert!(!is_fragment(&buf), "control packets are never loss-injected");
    }
}

#[test]
fn fragment_discriminator_is_stable() {
    // testkit's loss injection keys on the first byte; pin the contract.
    // Repair symbols ride the data path, so loss channels must drop them
    // like fragments; group acks are control traffic.
    let mut rng = Pcg64::seeded(7);
    for _ in 0..240 {
        let p = random_packet(&mut rng);
        let buf = p.encode();
        assert_eq!(
            is_fragment(&buf),
            matches!(p, Packet::Fragment(..) | Packet::RepairSymbol(..))
        );
    }
}
