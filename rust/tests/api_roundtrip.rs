//! Roundtrip matrix for the `janus::api` facade — the acceptance test of
//! the unified-API redesign. Deadline and Fidelity contracts run over
//! both the lossless in-memory transport and a 5%-loss deterministic
//! testkit channel, single-stream and pooled, with byte-exact delivery
//! and observer events asserted in order.

use janus::api::{
    mem_transport_pair, run_pair, CodecConfig, Contract, Dataset, EventLog, TransferEvent,
    TransferSpec,
};
use janus::model::NetParams;
use janus::refactor::{generate, GrfConfig};
use janus::testkit::{loss_transport_pair, LossTrace};
use janus::util::Pcg64;
use std::time::Duration;

fn test_dataset(seed: u64) -> Dataset {
    let mut rng = Pcg64::seeded(seed);
    let sizes = [40_000usize, 160_000, 320_000, 1_000_000];
    let eps = vec![0.004, 0.0005, 0.00006, 0.0000001];
    Dataset::new(
        sizes
            .iter()
            .map(|&sz| {
                let mut v = vec![0u8; sz];
                rng.fill_bytes(&mut v);
                v
            })
            .collect(),
        eps,
    )
    .unwrap()
}

fn spec(contract: Contract, streams: usize, initial_lambda: f64) -> TransferSpec {
    TransferSpec::builder()
        .contract(contract)
        .streams(streams)
        .net(NetParams { t: 0.0005, r: 200_000.0, lambda: 0.0, n: 32, s: 1024 })
        .initial_lambda(initial_lambda)
        .lambda_window(0.25)
        .idle_timeout(Duration::from_secs(5))
        .max_duration(Duration::from_secs(60))
        .build()
        .unwrap()
}

fn assert_byte_exact(levels: &[Option<Vec<u8>>], want: &Dataset) {
    assert_eq!(levels.len(), want.levels.len());
    for (li, (got, want)) in levels.iter().zip(&want.levels).enumerate() {
        assert_eq!(got.as_ref().expect("level delivered"), want, "level {li} differs");
    }
}

// ---------------------------------------------------------------- Fidelity

#[test]
fn fidelity_over_mem_single_stream_is_byte_exact() {
    let data = test_dataset(1);
    let (st, rt) = mem_transport_pair(1);
    let rep = run_pair(&spec(Contract::Fidelity(1e-7), 1, 0.0), st, rt, &data, None, None)
        .unwrap();
    assert_byte_exact(&rep.received.levels, &data);
    assert_eq!(rep.received.levels_recovered, 4);
    assert_eq!(rep.sent.passes, 0);
    assert!((rep.received.achieved_eps - 1e-7).abs() < 1e-15);
    assert!(rep.sent.single_stream().is_some(), "streams=1 routes single-stream");
}

#[test]
fn fidelity_over_mem_pooled_is_byte_exact() {
    let data = test_dataset(2);
    let (st, rt) = mem_transport_pair(4);
    let rep = run_pair(&spec(Contract::Fidelity(1e-7), 4, 0.0), st, rt, &data, None, None)
        .unwrap();
    assert_byte_exact(&rep.received.levels, &data);
    assert!(rep.sent.pooled().is_some(), "streams=4 routes pooled");
    let trace = rep.sent.trace().unwrap();
    assert_eq!(trace[0].per_stream.len(), 4);
    assert!(trace[0].per_stream.iter().all(|&c| c > 0), "every stream carried load");
}

#[test]
fn fidelity_sends_only_needed_levels() {
    let data = test_dataset(3);
    let (st, rt) = mem_transport_pair(1);
    // ε = 0.004 is satisfied by level 1 alone.
    let rep = run_pair(&spec(Contract::Fidelity(0.004), 1, 0.0), st, rt, &data, None, None)
        .unwrap();
    assert_eq!(rep.received.levels.len(), 1, "only level 1 in manifest");
    assert_eq!(rep.received.levels[0].as_ref().unwrap(), &data.levels[0]);
}

#[test]
fn fidelity_over_lossy_testkit_single_stream_recovers_exactly() {
    let data = test_dataset(4);
    // 5% deterministic fragment loss on the single (control) channel.
    let (st, rt) = loss_transport_pair(1, |_| LossTrace::seeded(0.05, 99));
    let s = spec(Contract::Fidelity(1e-7), 1, 0.05 * 200_000.0);
    let rep = run_pair(&s, st, rt, &data, None, None).unwrap();
    assert_byte_exact(&rep.received.levels, &data);
    assert!(
        rep.received.groups_recovered > 0 || rep.sent.passes > 0,
        "5% loss must exercise recovery"
    );
}

#[test]
fn fidelity_over_lossy_testkit_pooled_recovers_exactly() {
    let data = test_dataset(5);
    let (st, rt) = loss_transport_pair(4, |w| LossTrace::seeded(0.05, 7 ^ (w as u64 + 1)));
    let s = spec(Contract::Fidelity(1e-7), 4, 0.05 * 4.0 * 200_000.0);
    let rep = run_pair(&s, st, rt, &data, None, None).unwrap();
    assert_byte_exact(&rep.received.levels, &data);
    assert!(rep.received.groups_recovered > 0 || rep.sent.passes > 0);
}

// ---------------------------------------------------------------- Deadline

#[test]
fn deadline_over_mem_delivers_everything_within_budget() {
    let data = test_dataset(6);
    let (st, rt) = mem_transport_pair(1);
    let rep = run_pair(&spec(Contract::Deadline(60.0), 1, 0.0), st, rt, &data, None, None)
        .unwrap();
    // Lossless + generous τ: the full ladder arrives byte-exact.
    assert_byte_exact(&rep.received.levels, &data);
    assert_eq!(rep.sent.passes, 0, "deadline never retransmits");
}

#[test]
fn deadline_over_lossy_testkit_returns_exact_prefix() {
    let data = test_dataset(7);
    let (st, rt) = loss_transport_pair(1, |_| LossTrace::seeded(0.05, 1234));
    let s = spec(Contract::Deadline(60.0), 1, 0.05 * 200_000.0);
    let rep = run_pair(&s, st, rt, &data, None, None).unwrap();
    assert_eq!(rep.sent.passes, 0, "no retransmission under deadline contract");
    // Whatever prefix was recovered must be byte-exact.
    for i in 0..rep.received.levels_recovered {
        assert_eq!(rep.received.levels[i].as_ref().unwrap(), &data.levels[i]);
    }
    // The plan protects early levels: level 1 survives 5% loss.
    assert!(rep.received.levels_recovered >= 1, "level 1 must survive");
}

#[test]
fn pooled_deadline_matrix_meets_tau_in_virtual_time() {
    // The tentpole acceptance matrix: Deadline on the multi-stream pool,
    // {2, 4} streams × {0%, 5%, 20%} deterministic loss. τ is generous,
    // so the τ budget absorbs every λ̂-adapted retransmission pass:
    // everything arrives byte-exact, the virtual clock stays inside τ,
    // and the receiver's ε equals the sender's advertisement.
    for &streams in &[2usize, 4] {
        for &(loss, seed) in &[(0.0, 31u64), (0.05, 32), (0.20, 33)] {
            let data = test_dataset(0xDEAD ^ seed);
            let tau = 60.0;
            let s = spec(
                Contract::Deadline(tau),
                streams,
                loss * streams as f64 * 200_000.0,
            );
            let (st, rt) = loss_transport_pair(streams, |w| {
                LossTrace::seeded(loss, seed ^ (w as u64 + 1) * 0x9E37)
            });
            let rep = run_pair(&s, st, rt, &data, None, None).unwrap();
            let ctx = format!("streams={streams} loss={loss}");
            assert!(rep.sent.pooled().is_some(), "{ctx}: deadline routes pooled");
            let dl = rep.sent.deadline().expect("pooled deadline outcome");
            assert!(dl.met, "{ctx}: τ must be met, got {dl:?}");
            assert!(dl.virtual_elapsed <= tau, "{ctx}: {dl:?}");
            assert_byte_exact(&rep.received.levels, &data);
            assert!(
                (rep.received.achieved_eps - dl.advertised_eps).abs() < 1e-15,
                "{ctx}: receiver ε {} vs advertised {}",
                rep.received.achieved_eps,
                dl.advertised_eps
            );
            assert!(
                rep.sent.trace().unwrap().iter().all(|p| p.shed.is_empty()),
                "{ctx}: generous τ must not shed"
            );
        }
    }
}

#[test]
fn pooled_deadline_tight_budget_sheds_deterministically() {
    // A lying λ₀ = 0 sends pass 0 unprotected; 20% loss then forces the
    // pass-barrier replans to shed late levels. The decisions are a pure
    // function of (config, dataset, seeds): two runs must agree on the
    // full trace including sheds, and the receiver must certify exactly
    // the post-shed advertisement.
    let streams = 4usize;
    let run = || {
        let data = test_dataset(0x7A0);
        // τ ≈ 1.4 × the unprotected pass-0 air time over the aggregate
        // link: the clean pass fits, but after 20% of it dies the
        // residual budget cannot afford even the smallest level's
        // retransmission — the barrier must shed.
        let frags: f64 = data.levels.iter().map(|l| l.len().div_ceil(1024) as f64).sum();
        let tau = 1.4 * (0.0005 + frags / (streams as f64 * 200_000.0));
        let s = spec(Contract::Deadline(tau), streams, 0.0);
        let (st, rt) = loss_transport_pair(streams, |w| {
            LossTrace::seeded(0.20, 0xBAD ^ (w as u64 + 1) * 0x9E37)
        });
        let mut sender_log = EventLog::new();
        let rep = run_pair(&s, st, rt, &data, Some(&mut sender_log), None).unwrap();
        (rep, sender_log, data)
    };
    let (r1, log1, data) = run();
    let (r2, log2, _) = run();

    // Determinism: full sender and receiver traces, sheds included.
    assert_eq!(r1.sent.trace().unwrap(), r2.sent.trace().unwrap());
    assert_eq!(
        r1.received.pooled().unwrap().trace,
        r2.received.pooled().unwrap().trace
    );
    assert_eq!(r1.sent.deadline(), r2.sent.deadline());
    assert_eq!(log1.events, log2.events, "shed events replay identically");

    let dl = r1.sent.deadline().unwrap();
    let shed: Vec<_> = r1
        .sent
        .trace()
        .unwrap()
        .iter()
        .flat_map(|p| p.shed.clone())
        .collect();
    assert!(!shed.is_empty(), "tight τ under 20% loss must shed: {dl:?}");
    assert!(dl.met, "shedding keeps the virtual clock inside τ: {dl:?}");
    // Shed events mirror the trace, in order.
    let shed_events: Vec<_> = log1
        .events
        .iter()
        .filter_map(|e| match e {
            TransferEvent::LevelShed { level, kept_bytes, eps, .. } => {
                Some((*level, *kept_bytes, *eps))
            }
            _ => None,
        })
        .collect();
    assert_eq!(
        shed_events,
        shed.iter().map(|d| (d.level, d.kept_bytes, d.eps)).collect::<Vec<_>>()
    );
    // Receiver certifies exactly the post-shed advertisement, and the
    // recovered prefix is byte-exact.
    assert!((r1.received.achieved_eps - dl.advertised_eps).abs() < 1e-15);
    for li in 0..r1.received.levels_recovered {
        assert_eq!(r1.received.levels[li].as_ref().unwrap(), &data.levels[li]);
    }
    assert!(
        r1.received.levels_recovered < data.levels.len(),
        "a raw dataset has no plane cuts, so sheds abandon whole levels"
    );
}

#[test]
fn empty_dataset_is_a_typed_error_not_a_panic() {
    // `Dataset`'s fields are public: a caller can clear them after
    // construction. The facade must answer with a typed SpecError — the
    // pooled engine used to panic on `eps[eps.len() - 1]`.
    let mut data = test_dataset(40);
    data.levels.clear();
    data.eps.clear();
    let (mut st, _rt) = mem_transport_pair(4);
    let spec4 = spec(Contract::Fidelity(1e-7), 4, 0.0);
    let err = janus::api::Endpoint::new(spec4)
        .send(&mut st, &data, None)
        .unwrap_err();
    assert!(
        format!("{err}").contains("at least one level"),
        "typed empty-dataset error, got: {err}"
    );
    // Mismatched ladder lengths are equally typed.
    let mut data = test_dataset(41);
    data.eps.pop();
    let (mut st, _rt) = mem_transport_pair(1);
    let spec1 = spec(Contract::Fidelity(1e-7), 1, 0.0);
    let err = janus::api::Endpoint::new(spec1)
        .send(&mut st, &data, None)
        .unwrap_err();
    assert!(format!("{err}").contains("epsilon"), "{err}");
    // A broken (non-decreasing) ladder is typed too, on both routes.
    let mut data = test_dataset(42);
    data.eps[1] = data.eps[0];
    for streams in [1usize, 4] {
        let (mut st, _rt) = mem_transport_pair(streams);
        let err = janus::api::Endpoint::new(spec(Contract::Fidelity(1e-7), streams, 0.0))
            .send(&mut st, &data, None)
            .unwrap_err();
        assert!(format!("{err}").contains("epsilon"), "{err}");
    }
}

#[test]
fn mutated_codec_dataset_degrades_to_whole_level_cuts() {
    // Popping a codec dataset's public levels/eps leaves its plane cuts
    // describing levels that no longer exist. The facade must drop the
    // stale cuts and transfer the remaining rungs (losing only the
    // Deadline contract's bitplane shed granularity) — not panic inside
    // the engines' schedule asserts.
    let vol = generate(16, &GrfConfig::default(), 9);
    let cfg = CodecConfig { levels: 3, ladder: vec![8e-3, 4e-4], max_planes: 22 };
    let mut data = Dataset::from_volume(&vol, &cfg).unwrap();
    assert_eq!(data.levels.len(), 2);
    data.levels.pop();
    data.eps.pop(); // lengths stay equal; cuts keep one list too many
    let bound = *data.eps.last().unwrap();
    for streams in [1usize, 4] {
        let (st, rt) = mem_transport_pair(streams);
        let s = spec(Contract::Fidelity(bound), streams, 0.0);
        let rep = run_pair(&s, st, rt, &data, None, None).unwrap();
        assert_eq!(rep.received.levels.len(), 1, "streams={streams}");
        assert_eq!(rep.received.levels[0].as_ref().unwrap(), &data.levels[0]);
    }
}

// -------------------------------------------------------------- BestEffort

#[test]
fn best_effort_delivers_full_ladder() {
    let data = test_dataset(8);
    let (st, rt) = loss_transport_pair(4, |w| LossTrace::seeded(0.02, 40 + w as u64));
    let s = spec(Contract::BestEffort, 4, 0.02 * 4.0 * 200_000.0);
    let rep = run_pair(&s, st, rt, &data, None, None).unwrap();
    assert_byte_exact(&rep.received.levels, &data);
    assert_eq!(rep.received.levels_recovered, 4);
}

// ------------------------------------------------------------------ Codec

#[test]
fn codec_dataset_pooled_over_lossy_wire_meets_its_contract() {
    // The codec path through the pooled engine (ISSUE 4 satellite):
    // a volume-born dataset at 5% loss on 4 streams is byte-exact per
    // delivered segment and certifies the contracted ε on receive.
    let vol = generate(32, &GrfConfig::default(), 12);
    let cfg = CodecConfig { levels: 4, ladder: vec![4e-3, 5e-4, 8e-5], max_planes: 24 };
    let data = Dataset::from_volume(&vol, &cfg).unwrap();
    let contracted = *data.eps.last().unwrap();
    let (st, rt) = loss_transport_pair(4, |w| LossTrace::seeded(0.05, 90 + w as u64));
    let s = spec(Contract::Fidelity(contracted), 4, 0.05 * 4.0 * 200_000.0);
    let mut receiver_log = EventLog::new();
    let rep = run_pair(&s, st, rt, &data, None, Some(&mut receiver_log)).unwrap();

    // Byte-exact per delivered segment (each rung is a CRC'd segment
    // stream; exact bytes ⇒ every segment CRC verifies on decode).
    assert_byte_exact(&rep.received.levels, &data);
    assert!(rep.sent.pooled().is_some(), "streams=4 routes pooled");

    // The facade replayed the rungs progressively: one LevelDecoded per
    // rung, in level order, after every GroupRecovered.
    let decoded: Vec<(u8, f64)> = receiver_log
        .events
        .iter()
        .filter_map(|e| match e {
            TransferEvent::LevelDecoded { level, achieved_eps } => Some((*level, *achieved_eps)),
            _ => None,
        })
        .collect();
    assert_eq!(decoded.len(), data.levels.len());
    for (i, (level, eps)) in decoded.iter().enumerate() {
        assert_eq!(*level as usize, i);
        assert!((eps - data.eps[i]).abs() < 1e-15);
    }
    let first_decode = receiver_log
        .events
        .iter()
        .position(|e| matches!(e, TransferEvent::LevelDecoded { .. }))
        .unwrap();
    if let Some(last_group) = receiver_log
        .events
        .iter()
        .rposition(|e| matches!(e, TransferEvent::GroupRecovered { .. }))
    {
        assert!(last_group < first_decode);
    }

    // Certified reconstruction: the reported ε meets the contract and
    // bounds the ground truth.
    let codec = rep.received.codec.as_ref().expect("codec summary");
    assert_eq!(codec.rungs_decoded, data.levels.len());
    assert!(codec.achieved_eps <= contracted + 1e-15);
    let out = rep.received.decode_volume().expect("codec stream").expect("decodes");
    assert!(vol.linf_rel_error(&out.volume) <= out.achieved_eps + 1e-12);
    assert!((out.achieved_eps - codec.achieved_eps).abs() < 1e-15);
}

#[test]
fn raw_dataset_emits_no_codec_events() {
    let data = test_dataset(20);
    let (st, rt) = mem_transport_pair(1);
    let mut receiver_log = EventLog::new();
    let rep = run_pair(
        &spec(Contract::Fidelity(1e-7), 1, 0.0),
        st,
        rt,
        &data,
        None,
        Some(&mut receiver_log),
    )
    .unwrap();
    assert_byte_exact(&rep.received.levels, &data);
    assert!(rep.received.codec.is_none(), "raw datasets carry no codec summary");
    assert!(rep.received.decode_volume().is_none());
    assert!(receiver_log
        .events
        .iter()
        .all(|e| !matches!(e, TransferEvent::LevelDecoded { .. })));
}

// -------------------------------------------------------- Observer events

#[test]
fn lambda_reports_flow_back_to_the_sender() {
    let data = test_dataset(9);
    let (st, rt) = loss_transport_pair(1, |_| LossTrace::seeded(0.03, 13));
    let s = TransferSpec::builder()
        .contract(Contract::Fidelity(1e-7))
        .net(NetParams { t: 0.0005, r: 200_000.0, lambda: 0.0, n: 32, s: 1024 })
        .initial_lambda(0.03 * 200_000.0)
        // Tiny window: the whole transfer lasts ~10 ms of wall time.
        .lambda_window(0.002)
        .idle_timeout(Duration::from_secs(5))
        .max_duration(Duration::from_secs(60));
    let mut sender_log = EventLog::new();
    let mut receiver_log = EventLog::new();
    let rep = run_pair(
        &s.build().unwrap(),
        st,
        rt,
        &data,
        Some(&mut sender_log),
        Some(&mut receiver_log),
    )
    .unwrap();
    assert_byte_exact(&rep.received.levels, &data);
    assert!(
        !rep.sent.lambda_history.is_empty(),
        "sender must see λ̂ feedback"
    );
    // Both sides observed the λ̂ flow as typed events.
    let recv_lambda: Vec<f64> = receiver_log
        .events
        .iter()
        .filter_map(|e| match e {
            TransferEvent::LambdaUpdated { lambda } => Some(*lambda),
            _ => None,
        })
        .collect();
    assert!(!recv_lambda.is_empty(), "receiver emits LambdaUpdated");
    assert!(
        !sender_log
            .filtered(|e| matches!(e, TransferEvent::LambdaUpdated { .. }))
            .is_empty(),
        "sender emits LambdaUpdated on feedback"
    );
    // Quantitative accuracy (ported from the deleted session.rs test):
    // λ̂ must track the loss fraction times the *achieved* wire rate
    // (sleep-granularity pacing undershoots the nominal r).
    let achieved_rate = rep.sent.fragments_sent as f64 / rep.sent.duration;
    let expect = 0.03 * achieved_rate;
    let mean = janus::util::stats::mean(&recv_lambda);
    assert!(
        mean > 0.2 * expect && mean < 3.0 * expect,
        "λ̂ mean {mean} vs expected ≈{expect}"
    );
}

#[test]
fn single_stream_events_arrive_in_protocol_order() {
    let data = test_dataset(10);
    let (st, rt) = loss_transport_pair(1, |_| LossTrace::seeded(0.05, 55));
    let s = spec(Contract::Fidelity(1e-7), 1, 0.05 * 200_000.0);
    let mut sender_log = EventLog::new();
    let mut receiver_log = EventLog::new();
    let rep = run_pair(&s, st, rt, &data, Some(&mut sender_log), Some(&mut receiver_log))
        .unwrap();
    assert_byte_exact(&rep.received.levels, &data);

    let ev = &sender_log.events;
    assert!(!ev.is_empty());
    assert_eq!(ev[0], TransferEvent::PassStarted { pass: 0 }, "first event: pass 0");
    // PassStarted events strictly increase.
    let passes: Vec<u32> = ev
        .iter()
        .filter_map(|e| match e {
            TransferEvent::PassStarted { pass } => Some(*pass),
            _ => None,
        })
        .collect();
    assert!(passes.windows(2).all(|w| w[1] == w[0] + 1), "passes in order: {passes:?}");
    assert_eq!(passes.len() as u32, rep.sent.passes + 1, "one PassStarted per pass");
    // Each pass's StreamFinished follows its PassStarted.
    for &p in &passes {
        let started = ev
            .iter()
            .position(|e| *e == TransferEvent::PassStarted { pass: p })
            .unwrap();
        let finished = ev
            .iter()
            .position(|e| matches!(e, TransferEvent::StreamFinished { pass, .. } if *pass == p))
            .unwrap_or_else(|| panic!("no StreamFinished for pass {p}"));
        assert!(started < finished, "pass {p}: start before finish");
    }
    // The initial ParityAdapted comes after PassStarted{0} (fidelity
    // contracts always solve Eq. 8 at least once).
    let parity = ev
        .iter()
        .position(|e| matches!(e, TransferEvent::ParityAdapted { .. }))
        .expect("fidelity emits ParityAdapted");
    assert!(parity >= 1, "ParityAdapted after PassStarted");

    // Receiver side: groups recovered under loss, emitted during
    // reconstruction (after all LambdaUpdated events).
    if rep.received.groups_recovered > 0 {
        let rev = &receiver_log.events;
        let first_group = rev
            .iter()
            .position(|e| matches!(e, TransferEvent::GroupRecovered { .. }))
            .unwrap();
        let last_lambda = rev
            .iter()
            .rposition(|e| matches!(e, TransferEvent::LambdaUpdated { .. }));
        if let Some(l) = last_lambda {
            assert!(l < first_group, "λ̂ events precede reconstruction events");
        }
        assert_eq!(
            rev.iter()
                .filter(|e| matches!(e, TransferEvent::GroupRecovered { .. }))
                .count() as u64,
            rep.received.groups_recovered,
            "one GroupRecovered per recovered group"
        );
    }
}

#[test]
fn pooled_events_arrive_in_protocol_order() {
    let data = test_dataset(11);
    let streams = 4usize;
    let (st, rt) = loss_transport_pair(streams, |w| LossTrace::seeded(0.05, 70 + w as u64));
    let s = spec(Contract::Fidelity(1e-7), streams, 0.05 * 4.0 * 200_000.0);
    let mut sender_log = EventLog::new();
    let mut receiver_log = EventLog::new();
    let rep = run_pair(&s, st, rt, &data, Some(&mut sender_log), Some(&mut receiver_log))
        .unwrap();
    assert_byte_exact(&rep.received.levels, &data);

    let ev = &sender_log.events;
    assert_eq!(ev[0], TransferEvent::PassStarted { pass: 0 });
    assert_eq!(
        ev[1],
        TransferEvent::ParityAdapted {
            pass: 0,
            m: rep.sent.trace().unwrap()[0].m
        },
        "pass 0 parity follows pass start"
    );
    let total_passes = rep.sent.passes + 1;
    for p in 0..total_passes {
        let started = ev
            .iter()
            .position(|e| *e == TransferEvent::PassStarted { pass: p })
            .unwrap_or_else(|| panic!("no PassStarted for pass {p}"));
        // Exactly one ParityAdapted per pass, right at the barrier.
        let adapted = ev
            .iter()
            .position(|e| matches!(e, TransferEvent::ParityAdapted { pass, .. } if *pass == p))
            .unwrap();
        assert!(started < adapted);
        // Every stream reports StreamFinished for the pass, all after
        // ParityAdapted and before the pass's LambdaUpdated.
        let stream_idx: Vec<usize> = ev
            .iter()
            .enumerate()
            .filter_map(|(i, e)| match e {
                TransferEvent::StreamFinished { pass, .. } if *pass == p => Some(i),
                _ => None,
            })
            .collect();
        assert_eq!(stream_idx.len(), streams, "pass {p}: one finish per stream");
        assert!(stream_idx.iter().all(|&i| i > adapted));
        // The λ̂ barrier update for this pass comes after every stream.
        let lambda_after: Vec<usize> = ev
            .iter()
            .enumerate()
            .filter_map(|(i, e)| match e {
                TransferEvent::LambdaUpdated { .. } if i > adapted => Some(i),
                _ => None,
            })
            .collect();
        let pass_lambda = lambda_after
            .iter()
            .find(|&&i| stream_idx.iter().all(|&sidx| sidx < i))
            .copied()
            .unwrap_or_else(|| panic!("pass {p}: no barrier LambdaUpdated"));
        assert!(stream_idx.iter().all(|&i| i < pass_lambda));
    }
    // One barrier λ̂ per pass, matching the report's history.
    let lambdas: Vec<f64> = ev
        .iter()
        .filter_map(|e| match e {
            TransferEvent::LambdaUpdated { lambda } => Some(*lambda),
            _ => None,
        })
        .collect();
    assert_eq!(lambdas, rep.sent.lambda_history, "events mirror the λ̂ history");

    // Receiver side: every RS recovery shows up as a typed event.
    assert_eq!(
        receiver_log
            .events
            .iter()
            .filter(|e| matches!(e, TransferEvent::GroupRecovered { .. }))
            .count() as u64,
        rep.received.groups_recovered
    );
    assert!(rep.received.groups_recovered > 0, "5% loss must recover groups");
}

#[test]
fn fountain_with_multiple_streams_is_a_typed_spec_error() {
    use janus::api::SpecError;
    use janus::erasure::Backend;
    // The rateless backend owns its stream's repair schedule; the pooled
    // engine would shard one fountain across streams with colliding
    // symbol seeds. The builder must reject the combination up front
    // with a typed error naming the offending stream count.
    let err = TransferSpec::builder()
        .backend(Backend::Fountain)
        .streams(4)
        .build()
        .unwrap_err();
    assert_eq!(err, SpecError::FountainNeedsSingleStream(4));
    assert!(
        format!("{err}").contains("single"),
        "error must say fountain needs a single stream, got: {err}"
    );
    // streams(1) is the supported shape and must build.
    let spec = TransferSpec::builder()
        .backend(Backend::Fountain)
        .streams(1)
        .build()
        .expect("fountain with one stream is valid");
    assert_eq!(spec.backend(), Backend::Fountain);
}
