//! The lint gate: `cargo test` fails on any `janus lint` violation, and
//! every rule in the catalog is mutation-tested — a seeded violation of
//! each invariant must turn exactly that rule red (a rule that cannot
//! fail is not a check; DESIGN.md §13).

use janus::analysis::rules::{self, RULES};
use janus::analysis::{lint_root, workspace_root, SourceTree, Violation, DEFAULT_BUDGET};

fn load_real_tree() -> SourceTree {
    let root = workspace_root().expect("workspace root");
    SourceTree::load(&root).expect("load sources")
}

fn rules_hit(violations: &[Violation]) -> Vec<&'static str> {
    let mut hit: Vec<&'static str> = violations.iter().map(|v| v.rule).collect();
    hit.sort_unstable();
    hit.dedup();
    hit
}

// ---------------------------------------------------------------------------
// The gate itself
// ---------------------------------------------------------------------------

#[test]
fn real_tree_is_clean() {
    let root = workspace_root().expect("workspace root");
    let violations = lint_root(&root).expect("lint");
    for v in &violations {
        eprintln!("{v}");
    }
    assert!(
        violations.is_empty(),
        "`janus lint` found {} violation(s); fix them or waive them explicitly",
        violations.len()
    );
}

#[test]
fn every_rule_is_registered() {
    assert_eq!(
        RULES,
        &["sans-io-clock", "unsafe-audit", "datapath-no-alloc", "wire-pin", "no-deps"]
    );
}

// ---------------------------------------------------------------------------
// Mutation tests: seed one violation per rule, assert that rule (and
// only that rule) goes red.
// ---------------------------------------------------------------------------

#[test]
fn seeded_clock_read_in_engine_trips_sans_io_clock() {
    let mut tree = load_real_tree();
    tree.push_file(
        "rust/src/engine/synthetic.rs",
        "pub fn oops() -> std::time::Instant {\n    std::time::Instant::now()\n}\n",
    );
    let violations = rules::run_all(&tree, DEFAULT_BUDGET);
    assert_eq!(rules_hit(&violations), vec!["sans-io-clock"], "{violations:?}");
    assert_eq!(violations.len(), 1);
    assert_eq!(violations[0].path, "rust/src/engine/synthetic.rs");
    assert_eq!(violations[0].line, 2);
}

#[test]
fn clock_waiver_and_test_module_are_respected() {
    let mut tree = load_real_tree();
    tree.push_file(
        "rust/src/serve/synthetic.rs",
        concat!(
            "pub fn driver_edge() -> std::time::Instant {\n",
            "    // lint: allow(sans-io-clock): synthetic waiver under test\n",
            "    std::time::Instant::now()\n",
            "}\n",
            "#[cfg(test)]\n",
            "mod tests {\n",
            "    fn t() { let _ = std::time::Instant::now(); }\n",
            "}\n",
        ),
    );
    let violations = rules::sans_io_clock(&tree);
    assert!(violations.is_empty(), "waived + test-module reads must pass: {violations:?}");
    // A clock read in a comment or string must not trip the rule either.
    let mut tree = load_real_tree();
    tree.push_file(
        "rust/src/engine/synthetic.rs",
        "// Instant::now() is banned here\npub const T: &str = \"Instant::now()\";\n",
    );
    assert!(rules::sans_io_clock(&tree).is_empty());
}

#[test]
fn seeded_naked_unsafe_trips_unsafe_audit() {
    let mut tree = load_real_tree();
    tree.push_file(
        "rust/src/erasure/synthetic.rs",
        "pub fn oops(p: *const u8) -> u8 {\n    unsafe { *p }\n}\n",
    );
    let violations = rules::run_all(&tree, DEFAULT_BUDGET);
    assert_eq!(rules_hit(&violations), vec!["unsafe-audit"], "{violations:?}");
    // Two findings: missing SAFETY comment + missing budget entry.
    assert!(violations.iter().any(|v| v.message.contains("SAFETY")), "{violations:?}");
    assert!(violations.iter().any(|v| v.message.contains("budget")), "{violations:?}");
}

#[test]
fn safety_comment_walks_past_attributes_but_not_code() {
    let mut tree = SourceTree::default();
    tree.push_file(
        "rust/src/ok.rs",
        concat!(
            "// SAFETY: p is valid for reads (caller contract).\n",
            "#[inline]\n",
            "pub fn read(p: *const u8) -> u8 {\n",
            "    unsafe { *p }\n",
            "}\n",
        ),
    );
    tree.push_file("Cargo.toml", "[workspace]\n");
    tree.push_file("rust/Cargo.toml", "[package]\n");
    // The SAFETY comment sits above the *function*, but the contiguous
    // comment/attribute walk-up from the `unsafe {` line stops at the
    // `pub fn` code line — the justification must be adjacent.
    let violations = rules::unsafe_audit(&tree, "rust/src/ok.rs 1\n");
    assert_eq!(violations.len(), 1, "{violations:?}");
    assert!(violations[0].message.contains("SAFETY"));
    // Putting the comment directly above the block passes.
    let mut tree2 = SourceTree::default();
    tree2.push_file(
        "rust/src/ok.rs",
        concat!(
            "pub fn read(p: *const u8) -> u8 {\n",
            "    // SAFETY: p is valid for reads (caller contract).\n",
            "    unsafe { *p }\n",
            "}\n",
        ),
    );
    assert!(rules::unsafe_audit(&tree2, "rust/src/ok.rs 1\n").is_empty());
}

#[test]
fn stale_budget_trips_unsafe_audit_in_both_directions() {
    let tree = load_real_tree();
    // Undercount: pin kernel.rs one below its real count.
    let undercount = DEFAULT_BUDGET.replace(
        "rust/src/erasure/kernel.rs 14",
        "rust/src/erasure/kernel.rs 13",
    );
    assert_ne!(undercount, DEFAULT_BUDGET, "budget line moved; update this test");
    let violations = rules::unsafe_audit(&tree, &undercount);
    assert!(
        violations.iter().any(|v| v.message.contains("counted 14, budget pins 13")),
        "{violations:?}"
    );
    // Stale entry: a budget line for a file with no unsafe left.
    let mut stale = String::from(DEFAULT_BUDGET);
    stale.push_str("rust/src/erasure/rs.rs 2\n");
    let violations = rules::unsafe_audit(&tree, &stale);
    assert!(
        violations.iter().any(|v| v.message.contains("counted 0, budget pins 2")),
        "{violations:?}"
    );
}

#[test]
fn seeded_alloc_in_datapath_region_trips_datapath_no_alloc() {
    let mut tree = load_real_tree();
    tree.push_file(
        "rust/src/transport/synthetic.rs",
        concat!(
            "// lint: datapath\n",
            "pub fn hot(v: &[u8]) -> Vec<u8> {\n",
            "    v.to_vec()\n",
            "}\n",
            "// lint: end-datapath\n",
        ),
    );
    let violations = rules::run_all(&tree, DEFAULT_BUDGET);
    assert_eq!(rules_hit(&violations), vec!["datapath-no-alloc"], "{violations:?}");
    assert_eq!(violations.len(), 1);
    assert!(violations[0].message.contains(".to_vec()"));
    assert_eq!(violations[0].line, 3);
}

#[test]
fn unbalanced_datapath_markers_are_violations() {
    let mut tree = load_real_tree();
    tree.push_file("rust/src/a.rs", "// lint: datapath\nfn f() {}\n");
    tree.push_file("rust/src/b.rs", "fn g() {}\n// lint: end-datapath\n");
    let violations = rules::datapath_no_alloc(&tree);
    assert_eq!(violations.len(), 2, "{violations:?}");
    assert!(violations.iter().any(|v| v.message.contains("unclosed")));
    assert!(violations.iter().any(|v| v.message.contains("stray")));
}

#[test]
fn renumbered_wire_constant_trips_wire_pin() {
    let mut tree = load_real_tree();
    let packet = tree.file("rust/src/coordinator/packet.rs").expect("packet.rs").text.clone();
    let mutated = packet.replace("const KIND_REPAIR: u8 = 12;", "const KIND_REPAIR: u8 = 14;");
    assert_ne!(mutated, packet, "KIND_REPAIR declaration moved; update this test");
    assert!(tree.replace_file("rust/src/coordinator/packet.rs", &mutated));
    let violations = rules::run_all(&tree, DEFAULT_BUDGET);
    assert_eq!(rules_hit(&violations), vec!["wire-pin"], "{violations:?}");
    assert!(
        violations.iter().any(|v| v.message.contains("KIND_REPAIR")
            && v.message.contains("14")
            && v.message.contains("12")),
        "{violations:?}"
    );
}

#[test]
fn unpinned_new_discriminant_trips_wire_pin() {
    let mut tree = load_real_tree();
    let packet = tree.file("rust/src/coordinator/packet.rs").expect("packet.rs").text.clone();
    let mutated = format!("{packet}\nconst KIND_EXPERIMENTAL: u8 = 99;\n");
    assert!(tree.replace_file("rust/src/coordinator/packet.rs", &mutated));
    let violations = rules::wire_pin(&tree);
    assert_eq!(violations.len(), 1, "{violations:?}");
    assert!(violations[0].message.contains("KIND_EXPERIMENTAL"));
    assert!(violations[0].message.contains("not in the pinned table"));
}

#[test]
fn seeded_dependency_trips_no_deps() {
    let mut tree = load_real_tree();
    let manifest = tree.file("rust/Cargo.toml").expect("rust/Cargo.toml").text.clone();
    let mutated = manifest.replace("[dependencies]", "[dependencies]\nserde = \"1\"");
    assert_ne!(mutated, manifest, "[dependencies] section vanished; update this test");
    assert!(tree.replace_file("rust/Cargo.toml", &mutated));
    let violations = rules::run_all(&tree, DEFAULT_BUDGET);
    assert_eq!(rules_hit(&violations), vec!["no-deps"], "{violations:?}");
    assert!(violations[0].message.contains("serde"));
}

#[test]
fn xla_path_escape_hatch_is_tolerated() {
    let mut tree = load_real_tree();
    let manifest = tree.file("rust/Cargo.toml").expect("rust/Cargo.toml").text.clone();
    let mutated = manifest
        .replace("[dependencies]", "[dependencies]\nxla = { path = \"../vendor/xla\" }");
    assert_ne!(mutated, manifest);
    assert!(tree.replace_file("rust/Cargo.toml", &mutated));
    assert!(rules::no_deps(&tree).is_empty(), "the pjrt escape hatch is sanctioned");
}
