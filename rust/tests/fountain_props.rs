//! Property tests for the LT fountain backend (DESIGN.md §12): seeded
//! degree-distribution statistics, decode success at modest overhead
//! across loss patterns, peeling ≡ Gaussian-elimination (arrival-order
//! independence), and seed-determinism of the encode stream.

use janus::erasure::{FountainDecoder, LtCode, RobustSoliton};
use janus::model::fountain_overhead;
use janus::util::Pcg64;

fn group_data(k: usize, s: usize, seed: u64) -> Vec<u8> {
    let mut data = vec![0u8; k * s];
    Pcg64::seeded(seed).fill_bytes(&mut data);
    data
}

/// Generate symbol `esi` through a fresh scratch/out pair.
fn symbol(code: &LtCode, data: &[u8], s: usize, group: u32, esi: u32) -> Vec<u8> {
    let mut scratch = Vec::new();
    let mut out = vec![0u8; s];
    code.symbol_into(data, s, group, esi, &mut scratch, &mut out);
    out
}

#[test]
fn seeded_degree_statistics_match_the_distribution() {
    // The sender never sends a degree on the wire: the receiver re-draws
    // it from (seed, group, esi). So the *empirical* degree histogram of
    // the repair stream must match the robust-soliton the decoder
    // assumes — mean within a few percent at this sample size, degree-1
    // symbols present (they seed the peeling cascade), every neighbor
    // set in-range, distinct, and of the drawn size.
    for k in [16usize, 64, 192] {
        let code = LtCode::new(k, 0xD157).unwrap();
        let dist = code.distribution();
        let n = 20_000u32;
        let mut scratch = Vec::new();
        let mut sum = 0usize;
        let mut ones = 0usize;
        for esi in k as u32..k as u32 + n {
            code.neighbors_into(5, esi, &mut scratch);
            let d = scratch.len();
            assert!((1..=k).contains(&d), "k={k}: degree {d} out of range");
            let mut sorted = scratch.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), d, "k={k} esi={esi}: repeated neighbor");
            assert!(*sorted.last().unwrap() < k, "k={k}: neighbor out of range");
            sum += d;
            ones += usize::from(d == 1);
        }
        let empirical = sum as f64 / n as f64;
        let expected = dist.mean_degree();
        let rel = (empirical - expected).abs() / expected;
        assert!(
            rel < 0.10,
            "k={k}: empirical mean degree {empirical:.3} vs distribution {expected:.3}"
        );
        assert!(ones > 0, "k={k}: no degree-1 symbols in {n} draws");
    }
}

#[test]
fn decode_succeeds_at_modest_overhead_across_loss_patterns() {
    // The barrier-free τ model prices a fountain transfer at k·(1+ε)
    // symbols with ε = fountain_overhead(k). Feed the decoder under
    // four loss patterns — lossless, light random, heavy random, and
    // all-sources-lost — and check the model's ε (plus the decoder's
    // Gaussian-elimination cooldown margin) covers the median observed
    // overhead, with a hard 2k+16 ceiling on the worst case.
    let s = 64usize;
    for k in [8usize, 32, 64] {
        let eps = fountain_overhead(k);
        let budget = (k as f64 * eps).ceil() as usize + 10;
        for (pi, &loss) in [0.0f64, 0.05, 0.25, 1.0].iter().enumerate() {
            let mut extras: Vec<usize> = Vec::new();
            for trial in 0..11u64 {
                let seed = 0xF0_0D ^ (k as u64) << 16 ^ (pi as u64) << 8 ^ trial;
                let code = LtCode::new(k, seed).unwrap();
                let data = group_data(k, s, seed ^ 0x5A5A);
                let mut drop_rng = Pcg64::seeded(seed ^ 0xD409);
                let mut dec = FountainDecoder::new(k, s, seed, trial as u32).unwrap();
                let mut consumed = 0usize;
                for esi in 0..k as u32 {
                    if drop_rng.next_f64() < loss {
                        continue; // this source symbol died on the wire
                    }
                    consumed += 1;
                    if dec.add_symbol(esi, &symbol(&code, &data, s, trial as u32, esi)) {
                        break;
                    }
                }
                let mut esi = k as u32;
                while !dec.is_complete() {
                    assert!(
                        consumed <= 2 * k + 16,
                        "k={k} loss={loss} trial={trial}: {consumed} symbols and counting"
                    );
                    consumed += 1;
                    dec.add_symbol(esi, &symbol(&code, &data, s, trial as u32, esi));
                    esi += 1;
                }
                assert_eq!(dec.data(), &data[..], "k={k} loss={loss} trial={trial}");
                extras.push(consumed - k);
            }
            extras.sort_unstable();
            let median = extras[extras.len() / 2];
            assert!(
                median <= budget,
                "k={k} loss={loss}: median overhead {median} symbols > k·ε+GE margin {budget} \
                 (all trials: {extras:?})"
            );
        }
    }
}

#[test]
fn peeling_and_gaussian_elimination_agree_for_any_arrival_order() {
    // The same symbol set must decode to the same bytes whether the
    // degree-1 peeling cascade resolves it (sources first: every repair
    // reduces immediately) or the GF(2) Gauss-Jordan fallback does
    // (repairs first: peeling has nothing to seed on, so the solver
    // clears the stall). Arrival order is adversary-controlled on a
    // reordering network, so this is a correctness property, not a
    // performance one.
    let (k, s) = (16usize, 48usize);
    let seed = 0xBEEF;
    let group = 2u32;
    let code = LtCode::new(k, seed).unwrap();
    let data = group_data(k, s, 0xA11CE);
    // 12 surviving sources + 30 repair symbols: ample joint rank over
    // the 4 missing sources under either strategy (feeds stop early the
    // moment the decoder completes).
    let sources: Vec<u32> = (0..k as u32).filter(|e| e % 3 != 0 || *e > 9).collect();
    let repairs: Vec<u32> = (k as u32..k as u32 + 30).collect();
    let feed = |order: &[u32]| -> FountainDecoder {
        let mut dec = FountainDecoder::new(k, s, seed, group).unwrap();
        for &esi in order {
            dec.add_symbol(esi, &symbol(&code, &data, s, group, esi));
            if dec.is_complete() {
                break;
            }
        }
        dec
    };
    let mut forward: Vec<u32> = sources.clone();
    forward.extend(&repairs);
    let mut reversed: Vec<u32> = repairs.clone();
    reversed.extend(&sources);
    // A seeded shuffle as a third order.
    let mut shuffled = forward.clone();
    let mut rng = Pcg64::seeded(7);
    for i in (1..shuffled.len()).rev() {
        let j = rng.next_below(i as u64 + 1) as usize;
        shuffled.swap(i, j);
    }
    for (name, order) in
        [("sources-first", &forward), ("repairs-first", &reversed), ("shuffled", &shuffled)]
    {
        let dec = feed(order);
        assert!(dec.is_complete(), "{name}: decoder did not complete");
        assert_eq!(dec.data(), &data[..], "{name}: decoded bytes differ from source");
    }
}

#[test]
fn encode_stream_is_seed_deterministic() {
    let (k, s) = (24usize, 32usize);
    let data = group_data(k, s, 99);
    let a = LtCode::new(k, 0x1234).unwrap();
    let b = LtCode::new(k, 0x1234).unwrap();
    let c = LtCode::new(k, 0x4321).unwrap();
    let mut differs_seed = false;
    let mut differs_group = false;
    for esi in 0..(k as u32 + 64) {
        // Same (seed, group, esi, k) ⇒ identical bytes across instances.
        assert_eq!(
            symbol(&a, &data, s, 3, esi),
            symbol(&b, &data, s, 3, esi),
            "esi={esi}: same seed must generate identical symbols"
        );
        if esi >= k as u32 {
            differs_seed |= symbol(&a, &data, s, 3, esi) != symbol(&c, &data, s, 3, esi);
            differs_group |= symbol(&a, &data, s, 3, esi) != symbol(&a, &data, s, 4, esi);
        }
    }
    assert!(differs_seed, "seed never influenced the repair stream");
    assert!(differs_group, "group id never influenced the repair stream");
    // Systematic prefix ignores seed and group alike: it IS the source.
    for esi in 0..k as u32 {
        let frag = &data[esi as usize * s..(esi as usize + 1) * s];
        assert_eq!(&symbol(&c, &data, s, 8, esi)[..], frag);
    }
}

#[test]
fn default_seed_is_pinned() {
    // Both endpoints fall back to this constant for groups whose first
    // arrivals are systematic fragments (which carry no seed on the
    // wire); changing it is a wire-protocol break.
    assert_eq!(LtCode::DEFAULT_SEED, 0x4A41_4E55_535F_4C54);
    let d = RobustSoliton::new(32);
    assert_eq!(d.k(), 32);
}
