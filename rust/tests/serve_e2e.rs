//! End-to-end acceptance for `janus::serve`: one single-threaded daemon
//! loop multiplexing hundreds of concurrent transfers over two shared
//! sockets (transfer-id demux), with per-tenant budget admission in both
//! policies and a Real-mode interop check against the blocking
//! [`Endpoint`] facade dialing through a [`ServeTransport`].

use janus::api::{AdaptConfig, Contract, Dataset, Endpoint, TransferSpec};
use janus::coordinator::{ReceiverConfig, SenderConfig};
use janus::model::NetParams;
use janus::serve::{
    AdmissionPolicy, Daemon, ServeConfig, ServeTransport, TimeMode, TransferOutcome,
};
use janus::testkit::{FragmentLossChannel, LossTrace};
use janus::transport::channel::mem_pair;
use janus::util::Pcg64;
use std::time::Duration;

fn payload(id: u32, n: usize) -> Vec<u8> {
    let mut rng = Pcg64::seeded(0x5EED ^ u64::from(id));
    let mut v = vec![0u8; n];
    rng.fill_bytes(&mut v);
    v
}

fn sender_cfg(rate: f64, lambda0: f64) -> SenderConfig {
    SenderConfig {
        net: NetParams { t: 0.0005, r: rate, lambda: 0.0, n: 32, s: 1024 },
        contract: Contract::Fidelity(1e-7),
        initial_lambda: lambda0,
        max_duration: Duration::from_secs(600),
        plane_cuts: vec![],
        adapt: AdaptConfig::fixed(),
    }
}

fn recv_cfg() -> ReceiverConfig {
    ReceiverConfig {
        t_w: 3.0,
        idle_timeout: Duration::from_secs(60),
        max_duration: Duration::from_secs(600),
    }
}

fn virtual_daemon() -> Daemon {
    Daemon::new(ServeConfig { mode: TimeMode::Virtual, ..ServeConfig::default() })
}

#[test]
fn daemon_completes_256_concurrent_transfers_under_loss() {
    const N: u32 = 256;
    const SIZE: usize = 4096;
    let mut d = virtual_daemon();
    // Two shared sockets: every sender tags onto one lossy channel (5%
    // fragment loss, seeded), every receiver answers on the other end.
    let (a, b) = mem_pair();
    let lossy = FragmentLossChannel::new(a, LossTrace::seeded(0.05, 42));
    let tx = d.add_socket(Box::new(lossy));
    let rx = d.add_socket(Box::new(b));
    let tenants: Vec<usize> = (0..4)
        .map(|i| d.add_tenant(&format!("org-{i}"), u64::MAX, AdmissionPolicy::Queue))
        .collect();
    for id in 0..N {
        let t = tenants[(id % 4) as usize];
        d.register_receiver(t, rx, id, recv_cfg(), SIZE as u64).unwrap();
    }
    for id in 0..N {
        let t = tenants[(id % 4) as usize];
        d.register_sender(
            t,
            tx,
            id,
            sender_cfg(50_000.0, 2_500.0),
            vec![payload(id, SIZE)],
            vec![1e-7],
        )
        .unwrap();
    }
    assert_eq!(d.active_transfers(), 2 * N as usize);
    assert_eq!(d.queued_transfers(), 0);

    d.run_to_completion().unwrap();

    assert_eq!(d.active_transfers(), 0);
    let finished = d.take_finished();
    assert_eq!(finished.len(), 2 * N as usize);
    let mut received = 0u32;
    for f in &finished {
        assert!(
            f.outcome.is_ok(),
            "transfer {} on socket {} failed: {:?}",
            f.id,
            f.socket,
            f.outcome
        );
        if let TransferOutcome::Received(rep) = &f.outcome {
            assert_eq!(
                rep.levels[0].as_deref(),
                Some(&payload(f.id, SIZE)[..]),
                "transfer {} bytes differ",
                f.id
            );
            received += 1;
        }
    }
    assert_eq!(received, N, "every registered receiver must complete");
    for &t in &tenants {
        assert_eq!(d.tenant_used(t), 0, "budgets must drain with the transfers");
    }
    assert_eq!(d.dropped_untagged(), 0);
    assert_eq!(d.dropped_unknown(), 0);
}

#[test]
fn queue_policy_parks_submissions_until_budget_frees() {
    const SIZE: usize = 8192;
    let mut d = virtual_daemon();
    let (a, b) = mem_pair();
    let tx = d.add_socket(Box::new(a));
    let rx = d.add_socket(Box::new(b));
    // The sender tenant fits exactly two in-flight datasets; receivers
    // ride an unconstrained tenant so only sender admission is at play.
    let capped = d.add_tenant("capped", 2 * SIZE as u64, AdmissionPolicy::Queue);
    let sink = d.add_tenant("sink", u64::MAX, AdmissionPolicy::Queue);
    for id in 0..6u32 {
        d.register_receiver(sink, rx, id, recv_cfg(), SIZE as u64).unwrap();
        d.register_sender(
            capped,
            tx,
            id,
            sender_cfg(50_000.0, 0.0),
            vec![payload(id, SIZE)],
            vec![1e-7],
        )
        .unwrap();
    }
    assert_eq!(d.queued_transfers(), 4, "only two senders fit the budget");
    assert!(d.tenant_used(capped) <= 2 * SIZE as u64, "budget ceiling breached");

    d.run_to_completion().unwrap();

    assert_eq!(d.queued_transfers(), 0, "queued senders must drain FIFO");
    let finished = d.take_finished();
    assert_eq!(finished.len(), 12);
    for f in &finished {
        assert!(f.outcome.is_ok(), "transfer {}: {:?}", f.id, f.outcome);
        if let TransferOutcome::Received(rep) = &f.outcome {
            assert_eq!(rep.levels[0].as_deref(), Some(&payload(f.id, SIZE)[..]));
        }
    }
    assert_eq!(d.tenant_used(capped), 0);
}

#[test]
fn reject_policy_and_routing_guards_error_at_registration() {
    let mut d = virtual_daemon();
    let (a, b) = mem_pair();
    let tx = d.add_socket(Box::new(a));
    let rx = d.add_socket(Box::new(b));
    let strict = d.add_tenant("strict", 10_000, AdmissionPolicy::Reject);
    let cfg = sender_cfg(50_000.0, 0.0);
    d.register_sender(strict, tx, 1, cfg.clone(), vec![payload(1, 8_000)], vec![1e-7])
        .unwrap();
    // Over budget → typed rejection naming the tenant.
    let err = d
        .register_sender(strict, tx, 2, cfg.clone(), vec![payload(2, 8_000)], vec![1e-7])
        .unwrap_err();
    assert!(format!("{err}").contains("over budget"), "{err}");
    assert!(format!("{err}").contains("strict"), "{err}");
    // Duplicate (socket, id) → rejected regardless of budget.
    let err = d
        .register_sender(strict, tx, 1, cfg.clone(), vec![payload(1, 16)], vec![1e-7])
        .unwrap_err();
    assert!(format!("{err}").contains("already active"), "{err}");
    // A fragment size that cannot fit under the transfer tag is refused
    // up front, not silently truncated on the wire.
    let mut fat = cfg.clone();
    fat.net.s = 9_200;
    let err =
        d.register_sender(strict, tx, 3, fat, vec![payload(3, 16)], vec![1e-7]).unwrap_err();
    assert!(format!("{err}").contains("payload limit"), "{err}");
    // Unknown tenant / socket indexes are typed errors too.
    assert!(d.register_receiver(99, rx, 7, recv_cfg(), 1).is_err());
    assert!(d.register_receiver(strict, 99, 7, recv_cfg(), 1).is_err());
}

#[test]
fn real_mode_coding_offload_keeps_loop_responsive_under_large_groups() {
    // A tenant encoding huge FTGs (k ≈ 128, 2 KiB fragments — ~256 KiB
    // of GF math per group) shares the daemon with small transfers.
    // With coding offload enabled, the big groups' parity and decode
    // run on the pool, so no single slot-service call may stall the
    // event loop — and therefore the small transfers' timer deadlines —
    // beyond the offload bound.
    const BIG: usize = 512 * 1024;
    const SMALL: usize = 4096;
    const BIG_ID: u32 = 1000;
    let (a, b) = mem_pair();
    let mut d = Daemon::new(ServeConfig { coding_workers: 2, ..ServeConfig::default() });
    let tx = d.add_socket(Box::new(a));
    let rx = d.add_socket(Box::new(b));
    let tenant = d.add_tenant("lab", u64::MAX, AdmissionPolicy::Queue);
    let big_cfg = SenderConfig {
        net: NetParams { t: 0.0005, r: 50_000.0, lambda: 0.0, n: 132, s: 2048 },
        ..sender_cfg(50_000.0, 2_500.0)
    };
    d.register_receiver(tenant, rx, BIG_ID, recv_cfg(), BIG as u64).unwrap();
    for id in 0..8u32 {
        d.register_receiver(tenant, rx, id, recv_cfg(), SMALL as u64).unwrap();
    }
    d.register_sender(tenant, tx, BIG_ID, big_cfg, vec![payload(BIG_ID, BIG)], vec![1e-7])
        .unwrap();
    for id in 0..8u32 {
        d.register_sender(
            tenant,
            tx,
            id,
            sender_cfg(50_000.0, 2_500.0),
            vec![payload(id, SMALL)],
            vec![1e-7],
        )
        .unwrap();
    }

    d.run_to_completion().unwrap();

    let finished = d.take_finished();
    assert_eq!(finished.len(), 18);
    let mut big_jobs = 0u64;
    for f in &finished {
        assert!(f.outcome.is_ok(), "transfer {}: {:?}", f.id, f.outcome);
        if let TransferOutcome::Received(rep) = &f.outcome {
            let want = payload(f.id, if f.id == BIG_ID { BIG } else { SMALL });
            assert_eq!(rep.levels[0].as_deref(), Some(&want[..]), "transfer {} bytes", f.id);
        }
        if f.id == BIG_ID {
            big_jobs += f.coding_jobs;
        }
    }
    assert!(big_jobs > 0, "the big transfer must have run coding jobs on the pool");
    let (queued, completed) = d.coding_stats();
    assert!(queued > 0, "offload enabled: jobs must route through the pool");
    assert_eq!(queued, completed, "every queued job must complete");
    // The offload bound: no slot-service call (which no longer encodes
    // or decodes inline) may have stalled the shared loop for long.
    // Generous for noisy CI runners; inline k=128 coding would not even
    // be measured here, but its absence is what keeps service short.
    assert!(
        d.max_service_stall() < Duration::from_millis(250),
        "event loop stalled {:?} — coding not off the loop?",
        d.max_service_stall()
    );
}

#[test]
fn blocking_endpoint_dials_a_real_mode_daemon() {
    const SIZE: usize = 32_768;
    let (a, b) = mem_pair();
    let mut d = Daemon::new(ServeConfig::default()); // Real mode
    let sock = d.add_socket(Box::new(b));
    let tenant = d.add_tenant("edge", u64::MAX, AdmissionPolicy::Queue);
    d.register_receiver(tenant, sock, 7, recv_cfg(), SIZE as u64).unwrap();
    let daemon = std::thread::spawn(move || {
        d.run_to_completion().unwrap();
        d
    });

    let data = Dataset::new(vec![payload(7, SIZE)], vec![1e-7]).unwrap();
    let spec = TransferSpec::builder()
        .contract(Contract::Fidelity(1e-7))
        .streams(1)
        .net(NetParams { t: 0.0005, r: 50_000.0, lambda: 0.0, n: 32, s: 1024 })
        .initial_lambda(0.0)
        .lambda_window(3.0)
        .idle_timeout(Duration::from_secs(10))
        .max_duration(Duration::from_secs(60))
        .adaptation(AdaptConfig::fixed())
        .build()
        .unwrap();
    let mut transport = ServeTransport::new(a, 7);
    let summary = Endpoint::new(spec).send(&mut transport, &data, None).unwrap();
    assert_eq!(summary.data_fragments, (SIZE / 1024) as u64);

    let mut d = daemon.join().unwrap();
    let finished = d.take_finished();
    assert_eq!(finished.len(), 1);
    match &finished[0].outcome {
        TransferOutcome::Received(rep) => {
            assert_eq!(rep.levels[0].as_deref(), Some(&data.levels[0][..]));
        }
        other => panic!("expected Received, got {other:?}"),
    }
}
