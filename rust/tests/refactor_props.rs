//! Property tests for the refactoring primitives (ISSUE 4 satellites):
//! the bitplane truncation bound `2^(e_max − b)` over random, all-zero,
//! extreme-value and negative-heavy blocks; exact roundtrips for values
//! representable in the plane budget; and lifting roundtrips across the
//! full set of supported (d, levels) shapes.

use janus::refactor::{
    generate, try_decompose, try_reconstruct, validate_shape, BitplaneBlock, GrfConfig,
};
use janus::util::prop::{check, no_shrink, PropConfig};
use janus::util::Pcg64;

/// One generated bitplane case: values + (planes, truncation) budgets.
#[derive(Debug, Clone)]
struct BitplaneCase {
    values: Vec<f32>,
    planes: u8,
    keep: u8,
}

fn gen_case(rng: &mut Pcg64) -> BitplaneCase {
    let n = 1 + rng.next_below(300) as usize;
    let planes = (4 + rng.next_below(20)) as u8; // 4..=23
    let keep = (1 + rng.next_below(planes as u64)) as u8; // 1..=planes
    let kind = rng.next_below(4);
    let scale = 10f64.powi(rng.range(0, 7) as i32 - 3) as f32; // 1e-3..=1e3
    let values: Vec<f32> = match kind {
        // All-zero block: exact zeros must stay exact at any prefix.
        0 => vec![0.0; n],
        // One NaN-free extreme among ordinary values: the shared e_max
        // is pinned by the outlier, flushing the rest toward zero.
        1 => {
            let mut v: Vec<f32> = (0..n)
                .map(|_| ((rng.next_f64() * 2.0 - 1.0) as f32) * scale)
                .collect();
            let idx = rng.next_below(n as u64) as usize;
            v[idx] = 1.0e30;
            v
        }
        // Negative-heavy block: sign-plane handling under truncation.
        2 => (0..n)
            .map(|_| {
                let mag = rng.next_f64() as f32 * scale;
                if rng.bool_with(0.9) { -mag } else { mag }
            })
            .collect(),
        // Plain random block.
        _ => (0..n)
            .map(|_| ((rng.next_f64() * 2.0 - 1.0) as f32) * scale)
            .collect(),
    };
    BitplaneCase { values, planes, keep }
}

#[test]
fn truncated_decode_error_bounded_by_pow2_emax_minus_b() {
    check(
        &PropConfig { cases: 300, seed: 0xB17, ..Default::default() },
        gen_case,
        no_shrink,
        |case| {
            let block = BitplaneBlock::encode(&case.values, case.planes);
            // Serialize, truncate the byte stream to `keep` planes, and
            // decode the prefix — the full transport-shaped path.
            let bytes = block.to_bytes();
            let stride = case.values.len().div_ceil(8);
            let cut = 13 + stride + case.keep as usize * stride;
            let partial = BitplaneBlock::from_bytes(&bytes[..cut])
                .ok_or_else(|| "truncated parse failed".to_string())?;
            let decoded = partial.decode_prefix(case.keep);
            let bound = (2f64).powi(block.e_max - case.keep as i32);
            for (i, (a, b)) in case.values.iter().zip(&decoded).enumerate() {
                let err = (a - b).abs() as f64;
                if err > bound {
                    return Err(format!(
                        "coeff {i}: |{a} − {b}| = {err:.3e} > 2^({} − {}) = {bound:.3e}",
                        block.e_max, case.keep
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn the_bound_itself_halves_per_restored_plane() {
    // The per-step *worst case* `2^(e_max − b)` halves with every extra
    // plane — the property the codec's error planner relies on. (The
    // realized error of one coefficient is not monotone step-to-step:
    // mid-tread reconstruction can locally lose up to half a step when
    // a plane lands; only the bound contracts.)
    check(
        &PropConfig { cases: 100, seed: 0x5EED, ..Default::default() },
        gen_case,
        no_shrink,
        |case| {
            let block = BitplaneBlock::encode(&case.values, case.planes);
            for used in 1..=case.planes {
                let decoded = block.decode_prefix(used);
                let bound = (2f64).powi(block.e_max - used as i32);
                for (a, b) in case.values.iter().zip(&decoded) {
                    let err = (a - b).abs() as f64;
                    if err > bound {
                        return Err(format!(
                            "{used} planes: err {err:.3e} > bound {bound:.3e}"
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn roundtrip_exact_for_values_representable_in_the_plane_budget() {
    // Values of the form q·2^(e − p) with q < 2^p quantize exactly, so
    // a full-plane decode must reproduce them bit for bit.
    check(
        &PropConfig { cases: 200, seed: 0xE8AC7, ..Default::default() },
        |rng| {
            let n = 2 + rng.next_below(200) as usize;
            let p = (3 + rng.next_below(18)) as u8; // 3..=20 (fits f32 exactly)
            let e = rng.range(0, 9) as i32 - 4; // -4..=4
            let mut q: Vec<u32> = (0..n)
                .map(|_| rng.next_below(1u64 << p) as u32)
                .collect();
            // Pin e_max by making the largest magnitude top out.
            let idx = rng.next_below(n as u64) as usize;
            q[idx] = (1u32 << p) - 1;
            let signs: Vec<bool> = (0..n).map(|_| rng.bool_with(0.5)).collect();
            (p, e, q, signs)
        },
        no_shrink,
        |(p, e, q, signs)| {
            let lsb = (2f64).powi(*e - *p as i32);
            let values: Vec<f32> = q
                .iter()
                .zip(signs)
                .map(|(&qi, &neg)| {
                    let v = (qi as f64 * lsb) as f32;
                    if neg {
                        -v
                    } else {
                        v
                    }
                })
                .collect();
            let block = BitplaneBlock::encode(&values, *p);
            if block.e_max != *e {
                return Err(format!("e_max {} (expected {e})", block.e_max));
            }
            let decoded = block.decode();
            for (i, (a, b)) in values.iter().zip(&decoded).enumerate() {
                // Exact equality; ±0.0 compare equal, which is fine.
                if a != b {
                    return Err(format!("coeff {i}: {a} != {b}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn lifting_roundtrip_over_all_supported_shapes() {
    // Every (d, levels) accepted by validate_shape must reconstruct to
    // float accuracy — including non-power-of-two dimensions.
    for d in [2usize, 4, 6, 8, 12, 16, 20, 24] {
        for levels in 1..=5usize {
            if validate_shape(d, levels).is_err() {
                continue;
            }
            let vol = generate(d, &GrfConfig::default(), (d * 31 + levels) as u64);
            let bufs = try_decompose(&vol, levels).expect("validated shape");
            let refs: Vec<&[f32]> = bufs.iter().map(|b| b.as_slice()).collect();
            let back = try_reconstruct(&refs, levels, levels, d).expect("same shape");
            let err = vol.linf_rel_error(&back);
            assert!(err < 1e-4, "d={d} L={levels}: roundtrip ε = {err}");
        }
    }
}
