//! Integration: PJRT-loaded HLO artifacts vs the native Rust refactorer.
//!
//! This is the cross-layer correctness seal: the artifacts were authored
//! by JAX+Pallas (L2/L1), and the Rust mirror must agree bit-for-bit (to
//! f32 tolerance) when executed through the `xla` crate's PJRT client —
//! proving the three layers compose.
//!
//! Requires `make artifacts` (the default D=64, L=4 set) **and** the
//! `pjrt` cargo feature (the `xla` crate is not in the offline vendored
//! set, so this whole suite compiles away without it — the native mirror
//! in `janus::refactor` is covered by unit tests regardless).
#![cfg(feature = "pjrt")]

use janus::refactor::{decompose, generate, reconstruct, GrfConfig, Volume};
use janus::runtime::{default_artifact_dir, F32Input, Runtime};

const D: usize = 64;
const L: usize = 4;

fn runtime() -> Runtime {
    let dir = default_artifact_dir();
    assert!(
        dir.join("manifest.tsv").exists(),
        "artifacts missing at {dir:?} — run `make artifacts` first"
    );
    Runtime::open(dir).expect("open artifact runtime")
}

fn test_volume(seed: u64) -> Volume {
    generate(D, &GrfConfig::default(), seed)
}

fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    let mut worst = 0f32;
    for (x, y) in a.iter().zip(b) {
        worst = worst.max((x - y).abs());
    }
    assert!(worst <= tol, "{what}: max abs diff {worst} > {tol}");
}

#[test]
fn artifact_refactor_matches_native() {
    let mut rt = runtime();
    let vol = test_volume(11);
    let name = format!("refactor_d{D}_l{L}");
    let outs = rt
        .run_f32(&name, &[F32Input::shaped(&vol.data, &[D, D, D])])
        .expect("run refactor artifact");
    assert_eq!(outs.len(), L, "one buffer per level");
    let native = decompose(&vol, L);
    for (i, (pjrt, nat)) in outs.iter().zip(&native).enumerate() {
        assert_close(pjrt, nat, 1e-4, &format!("level {}", i + 1));
    }
}

#[test]
fn artifact_reconstruct_full_roundtrip() {
    let mut rt = runtime();
    let vol = test_volume(12);
    let refactor_name = format!("refactor_d{D}_l{L}");
    let levels = rt
        .run_f32(&refactor_name, &[F32Input::shaped(&vol.data, &[D, D, D])])
        .unwrap();
    let recon_name = format!("reconstruct_d{D}_l{L}_u{L}");
    let inputs: Vec<F32Input> = levels.iter().map(|l| F32Input::vec(l)).collect();
    let out = rt.run_f32(&recon_name, &inputs).unwrap();
    assert_eq!(out.len(), 1);
    assert_close(&out[0], &vol.data, 2e-4, "full reconstruction");
}

#[test]
fn artifact_progressive_reconstruction_matches_native_and_ladder() {
    let mut rt = runtime();
    let vol = test_volume(13);
    let native_levels = decompose(&vol, L);
    let mut prev_err = f64::INFINITY;
    for used in 1..=L {
        let name = format!("reconstruct_d{D}_l{L}_u{used}");
        let inputs: Vec<F32Input> = native_levels[..used]
            .iter()
            .map(|l| F32Input::vec(l))
            .collect();
        let out = rt.run_f32(&name, &inputs).unwrap();
        // Native mirror agrees with the artifact.
        let refs: Vec<&[f32]> = native_levels[..used].iter().map(|l| l.as_slice()).collect();
        let native = reconstruct(&refs, used, L, D);
        assert_close(&out[0], &native.data, 2e-4, &format!("reconstruct u={used}"));
        // And the ε ladder decreases.
        let approx = Volume::new(D, out[0].clone());
        let err = vol.linf_rel_error(&approx);
        assert!(err < prev_err, "ε did not decrease at u={used}: {err} vs {prev_err}");
        prev_err = err;
    }
    assert!(prev_err < 1e-4, "full reconstruction ε too high: {prev_err}");
}

#[test]
fn artifact_error_metric_matches_native() {
    let mut rt = runtime();
    let a = test_volume(14);
    let mut b = a.clone();
    for v in b.data.iter_mut().take(1000) {
        *v += 0.01;
    }
    let name = format!("linf_error_d{D}");
    let out = rt
        .run_f32(
            &name,
            &[
                F32Input::shaped(&a.data, &[D, D, D]),
                F32Input::shaped(&b.data, &[D, D, D]),
            ],
        )
        .unwrap();
    let native = a.linf_rel_error(&Volume::new(D, b.data.clone())) as f32;
    assert!(
        (out[0][0] - native).abs() < 1e-6,
        "pjrt {} vs native {native}",
        out[0][0]
    );
}

#[test]
fn manifest_exposes_expected_artifacts() {
    let rt = runtime();
    let names = rt.names();
    assert!(names.contains(&format!("refactor_d{D}_l{L}").as_str()));
    for u in 1..=L {
        assert!(names.contains(&format!("reconstruct_d{D}_l{L}_u{u}").as_str()));
    }
    assert_eq!(rt.arity(&format!("refactor_d{D}_l{L}")), Some(1));
    assert_eq!(rt.arity(&format!("reconstruct_d{D}_l{L}_u3")), Some(3));
}
