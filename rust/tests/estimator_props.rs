//! Property tests for the loss-estimation stack feeding the adaptive
//! layer: the Gilbert-Elliott channel model (`sim::hmm`), the estimator
//! family (`coordinator::estimate`, re-exported through
//! `sim::estimator`), and the pass-barrier two-state burst/residual
//! estimator — all deterministic under seeds on the virtual clock.

use janus::coordinator::estimate::{
    EwmaEstimator, LambdaEstimator, PassObservation, TwoStateEstimator, WindowEstimator,
};
use janus::sim::estimator::tracking_rmse;
use janus::sim::hmm::{HmmConfig, HmmLoss};
use janus::sim::loss::LossProcess;

const RATE: f64 = 10_000.0;

/// Sample `n` fragment fates from a Gilbert-Elliott chain observed at
/// `RATE` fragments/s (one-packet-service-time TTL, like the testkit).
fn ge_drops(mean_loss: f64, burst_len: f64, seed: u64, n: u64) -> Vec<bool> {
    let cfg = HmmConfig::gilbert_elliott(mean_loss, burst_len, RATE);
    let mut loss = HmmLoss::with_ttl(cfg, seed, 1.0 / RATE);
    (0..n).map(|i| loss.is_lost(i as f64 / RATE)).collect()
}

/// (loss fraction, mean run length) of a drop sequence.
fn shape(drops: &[bool]) -> (f64, f64) {
    let lost = drops.iter().filter(|&&d| d).count() as f64;
    let mut runs = 0u64;
    let mut prev = false;
    for &d in drops {
        if d && !prev {
            runs += 1;
        }
        prev = d;
    }
    (lost / drops.len() as f64, if runs == 0 { 0.0 } else { lost / runs as f64 })
}

#[test]
fn gilbert_elliott_hits_the_stationary_loss_rate() {
    // π_bad = dwell_bad / (dwell_bad + dwell_good) = mean_loss by
    // construction; the empirical fraction must match it, and the run
    // structure must be bursty (mean run ≫ i.i.d.'s 1/(1−p)).
    for (mean, burst) in [(0.05, 4.0), (0.2, 8.0), (0.4, 16.0)] {
        let drops = ge_drops(mean, burst, 0x6E0d ^ (burst as u64), 400_000);
        let (frac, mean_run) = shape(&drops);
        assert!(
            (frac - mean).abs() / mean < 0.25,
            "mean={mean} burst={burst}: stationary loss {frac}"
        );
        let iid_run = 1.0 / (1.0 - mean);
        assert!(
            mean_run > 2.0 * iid_run,
            "mean={mean} burst={burst}: run {mean_run} vs iid {iid_run}"
        );
    }
}

#[test]
fn gilbert_elliott_is_bit_identical_under_a_seed() {
    let a = ge_drops(0.2, 8.0, 42, 100_000);
    let b = ge_drops(0.2, 8.0, 42, 100_000);
    assert_eq!(a, b, "same seed must replay the same fates");
    let c = ge_drops(0.2, 8.0, 43, 100_000);
    assert_ne!(a, c, "different seeds must differ somewhere");
}

#[test]
fn window_and_ewma_track_the_paper_hmm() {
    // Both engine-side estimators bound their RMSE against the 3-state
    // paper chain's true λ(t) (states at 19/383/957 losses/s), and the
    // score itself is deterministic under the seed.
    let r = 19_144.0;
    let run = |mk: &mut dyn LambdaEstimator| {
        let mut loss = HmmLoss::paper_default_with_ttl(11, 1.0 / r);
        tracking_rmse(mk, &mut loss, r, 120.0)
    };
    let w = run(&mut WindowEstimator::new(3.0));
    let e = run(&mut EwmaEstimator::new(1.0, 0.25));
    assert!(w.is_finite() && w > 0.0 && w < 500.0, "window rmse {w}");
    assert!(e.is_finite() && e > 0.0 && e < 500.0, "ewma rmse {e}");
    let w2 = run(&mut WindowEstimator::new(3.0));
    assert_eq!(w, w2, "tracking_rmse must be deterministic under a seed");
}

/// Chunk a drop sequence into pass-barrier observations exactly the way
/// the pooled receiver accounts them (runs = maximal gaps, burst_lost =
/// losses in runs of length ≥ 2).
fn observe_chunks(drops: &[bool], chunk: usize) -> TwoStateEstimator {
    let mut est = TwoStateEstimator::new(0.5);
    for ch in drops.chunks(chunk) {
        let offered = ch.len() as u64;
        let lost = ch.iter().filter(|&&d| d).count() as u64;
        let mut runs = 0u32;
        let mut burst_lost = 0u64;
        let mut run_len = 0u64;
        for &d in ch {
            if d {
                run_len += 1;
            } else if run_len > 0 {
                runs += 1;
                if run_len >= 2 {
                    burst_lost += run_len;
                }
                run_len = 0;
            }
        }
        if run_len > 0 {
            runs += 1;
            if run_len >= 2 {
                burst_lost += run_len;
            }
        }
        est.observe_pass(&PassObservation {
            elapsed: offered as f64 / RATE,
            offered,
            received: offered - lost,
            runs,
            burst_lost,
            rate: RATE,
        });
    }
    est
}

#[test]
fn two_state_estimator_recovers_burst_length_from_ge_ground_truth() {
    let drops = ge_drops(0.2, 8.0, 99, 400_000);
    let est = observe_chunks(&drops, 5_000);
    let b = est.burst_len();
    assert!(
        (4.0..=16.0).contains(&b),
        "b̂={b} should recover the configured burst ≈ 8"
    );
    let lam = est.lambda_total().expect("warmed up");
    let expect = 0.2 * RATE;
    assert!(
        (lam - expect).abs() / expect < 0.35,
        "λ̂={lam} vs stationary {expect}"
    );
    // Burst-dominated channel: most of λ̂ sits in the burst component.
    assert!(
        est.lambda_burst() > est.lambda_residual(),
        "burst {} vs residual {}",
        est.lambda_burst(),
        est.lambda_residual()
    );
}

#[test]
fn two_state_estimator_sees_iid_loss_as_unit_bursts() {
    // Same mean λ, i.i.d. shape: b̂ stays near the i.i.d. run length
    // 1/(1−p) = 1.25, far below the burst classifier's threshold — the
    // discrimination the engines rely on.
    let mut rng = janus::util::Pcg64::seeded(7);
    let drops: Vec<bool> = (0..400_000).map(|_| rng.bool_with(0.2)).collect();
    let est = observe_chunks(&drops, 5_000);
    let b = est.burst_len();
    assert!(b < 2.0, "i.i.d. 20% loss must not look bursty: b̂={b}");
    let lam = est.lambda_total().expect("warmed up");
    let expect = 0.2 * RATE;
    assert!((lam - expect).abs() / expect < 0.15, "λ̂={lam}");
}
