//! Steady-state allocation regression test (ISSUE 3).
//!
//! After warm-up, the per-fragment data path — slice → `encode_strided`
//! → wire encode → mem channel (pooled frames) → `recv_into` →
//! `PacketView` decode → arena store — must perform **zero** heap
//! allocations per fragment. A counting `#[global_allocator]` measures
//! the steady-state loop exactly; any regression (a stray `to_vec`, a
//! `Vec` in a hot struct, a growing buffer) fails the assertion.
//!
//! This file intentionally holds a single test: the counter is global,
//! so a sibling test running on another thread would pollute the
//! measurement.

use janus::coordinator::arena::FtgArena;
use janus::coordinator::packet::{
    encode_fragment_into, FragmentHeader, PacketView, MAX_DATAGRAM,
};
use janus::erasure::RsCode;
use janus::transport::channel::{mem_pair, Datagram};
use std::alloc::{GlobalAlloc, Layout, System};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

const K: usize = 8;
const M: usize = 4;
const S: usize = 1024;
const GROUPS: u32 = 16;

/// One full sender→receiver round over every group id, ending with the
/// group table reset to "empty but allocated" so the next round reuses
/// everything — the shape of a steady-state retransmission regime.
#[allow(clippy::too_many_arguments)]
fn run_round(
    code: &RsCode,
    tx: &mut impl Datagram,
    rx: &mut impl Datagram,
    send_arena: &mut FtgArena,
    groups: &mut HashMap<(u8, u32), FtgArena>,
    out: &mut Vec<u8>,
    rbuf: &mut [u8],
    data: &[u8],
) {
    for ftg in 0..GROUPS {
        // Sender: slice into the reused arena, encode parity in place.
        send_arena.reset(K as u8, M as u8, S);
        for i in 0..K {
            send_arena.slot_mut(i).copy_from_slice(&data[i * S..(i + 1) * S]);
        }
        send_arena.encode_parity(code).expect("encode");
        for idx in 0..send_arena.slots() {
            let hdr = FragmentHeader {
                level: 0,
                stream: 0,
                ftg,
                index: idx as u8,
                k: K as u8,
                m: M as u8,
                seq: 0,
                pass: 0,
            };
            encode_fragment_into(&hdr, send_arena.slot(idx), out);
            tx.send(out);
        }
        // Receiver: drain the group — the per-fragment store loop.
        for _ in 0..K + M {
            let n = rx
                .recv_into(rbuf, Duration::from_millis(500))
                .expect("fragment must arrive");
            match PacketView::decode(&rbuf[..n]).expect("valid datagram") {
                PacketView::Fragment(view) => {
                    let h = view.header;
                    let g = groups
                        .entry((h.level, h.ftg))
                        .or_insert_with(|| FtgArena::new(h.k, h.m, S));
                    assert!(g.insert(h.index as usize, view.payload));
                }
                other => panic!("unexpected control packet {other:?}"),
            }
        }
    }
    // Clear presence (keeping every allocation) so the next round's
    // inserts really copy payloads again.
    for g in groups.values_mut() {
        g.reset(K as u8, M as u8, S);
    }
}

#[test]
fn steady_state_datapath_is_allocation_free() {
    let code = RsCode::new(K, M).unwrap();
    let (mut tx, mut rx) = mem_pair();
    let mut send_arena = FtgArena::new(K as u8, M as u8, S);
    let mut groups: HashMap<(u8, u32), FtgArena> = HashMap::new();
    let mut out = Vec::with_capacity(MAX_DATAGRAM);
    let mut rbuf = vec![0u8; MAX_DATAGRAM];
    let data: Vec<u8> = (0..K * S).map(|i| i as u8).collect();

    // Warm-up: populates the frame pool, the channel's ring buffer, the
    // group table, the SIMD-dispatch cache, and the encode tables.
    for _ in 0..3 {
        run_round(
            &code, &mut tx, &mut rx, &mut send_arena, &mut groups, &mut out, &mut rbuf,
            &data,
        );
    }

    let before = ALLOCS.load(Ordering::Relaxed);
    const ROUNDS: u64 = 5;
    for _ in 0..ROUNDS {
        run_round(
            &code, &mut tx, &mut rx, &mut send_arena, &mut groups, &mut out, &mut rbuf,
            &data,
        );
    }
    let after = ALLOCS.load(Ordering::Relaxed);

    let fragments = ROUNDS * GROUPS as u64 * (K + M) as u64;
    assert_eq!(
        after - before,
        0,
        "steady-state datapath performed {} allocations over {} fragments",
        after - before,
        fragments
    );

    // Sanity: the loop really moved data — every group decodable, and
    // the frame pool recycled instead of growing.
    assert_eq!(groups.len(), GROUPS as usize);
    let (fresh, recycled) = tx.frame_pool().stats();
    assert!(recycled > fresh, "frame pool must recycle in steady state");
}
