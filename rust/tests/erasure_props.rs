//! Property tests for the erasure-coding substrate: Reed–Solomon
//! recovery under *every* admissible erasure pattern, and the GF(2^8)
//! field axioms (mini-prop framework; proptest is not in the offline
//! vendored crate set).

use janus::coordinator::arena::FtgArena;
use janus::erasure::gf256;
use janus::erasure::kernel::{self, KernelTier};
use janus::erasure::{CodingPool, RsCode};
use janus::util::prop::{check, no_shrink, PropConfig};
use janus::util::Pcg64;

/// All index subsets of `{0..n}` with exactly `j` elements.
fn combinations(n: usize, j: usize) -> Vec<Vec<usize>> {
    if j == 0 {
        return vec![vec![]];
    }
    if n < j {
        return vec![];
    }
    let mut out = combinations(n - 1, j);
    for mut c in combinations(n - 1, j - 1) {
        c.push(n - 1);
        out.push(c);
    }
    out
}

#[test]
fn prop_rs_roundtrips_under_every_erasure_pattern_up_to_m() {
    // For random small (k, m): encode random data, then for EVERY loss
    // pattern of 0..=m erasures the survivors must reconstruct the data
    // exactly (the MDS guarantee the protocol's recovery relies on).
    check(
        &PropConfig { cases: 40, ..Default::default() },
        |rng| {
            let k = rng.range(1, 7); // 1..=6
            let m = rng.range(0, 5); // 0..=4
            (k, m, rng.next_u64())
        },
        no_shrink,
        |&(k, m, seed)| {
            let n = k + m;
            let mut rng = Pcg64::seeded(seed);
            let code = RsCode::new(k, m).map_err(|e| e.to_string())?;
            let data: Vec<Vec<u8>> = (0..k)
                .map(|_| {
                    let mut f = vec![0u8; 24];
                    rng.fill_bytes(&mut f);
                    f
                })
                .collect();
            let refs: Vec<&[u8]> = data.iter().map(|f| f.as_slice()).collect();
            let parity = code.encode(&refs).map_err(|e| e.to_string())?;
            let all: Vec<Vec<u8>> = data.iter().chain(parity.iter()).cloned().collect();
            for j in 0..=m {
                for lost in combinations(n, j) {
                    let shards: Vec<(usize, &[u8])> = (0..n)
                        .filter(|i| !lost.contains(i))
                        .map(|i| (i, all[i].as_slice()))
                        .collect();
                    let got = code.reconstruct(&shards).map_err(|e| {
                        format!("k={k} m={m} lost={lost:?}: {e}")
                    })?;
                    if got != data {
                        return Err(format!("wrong bytes: k={k} m={m} lost={lost:?}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn rs_exhaustive_patterns_at_paper_shape() {
    // One fixed paper-flavoured geometry, exhaustively: (k, m) = (8, 3),
    // every pattern of exactly m = 3 losses (C(11,3) = 165).
    let (k, m) = (8usize, 3usize);
    let code = RsCode::new(k, m).unwrap();
    let mut rng = Pcg64::seeded(0xE5A);
    let data: Vec<Vec<u8>> = (0..k)
        .map(|_| {
            let mut f = vec![0u8; 128];
            rng.fill_bytes(&mut f);
            f
        })
        .collect();
    let refs: Vec<&[u8]> = data.iter().map(|f| f.as_slice()).collect();
    let parity = code.encode(&refs).unwrap();
    let all: Vec<Vec<u8>> = data.iter().chain(parity.iter()).cloned().collect();
    let mut patterns = 0;
    for lost in combinations(k + m, m) {
        let shards: Vec<(usize, &[u8])> = (0..k + m)
            .filter(|i| !lost.contains(i))
            .map(|i| (i, all[i].as_slice()))
            .collect();
        assert_eq!(code.reconstruct(&shards).unwrap(), data, "lost={lost:?}");
        patterns += 1;
    }
    assert_eq!(patterns, 165);
}

#[test]
fn prop_rs_fails_loudly_beyond_m_losses() {
    // m+1 erasures leave < k shards when we also drop data: the API must
    // return an error, never fabricate data.
    check(
        &PropConfig { cases: 40, ..Default::default() },
        |rng| (rng.range(2, 8), rng.range(1, 4), rng.next_u64()),
        no_shrink,
        |&(k, m, seed)| {
            let code = RsCode::new(k, m).map_err(|e| e.to_string())?;
            let mut rng = Pcg64::seeded(seed);
            let data: Vec<Vec<u8>> = (0..k)
                .map(|_| {
                    let mut f = vec![0u8; 16];
                    rng.fill_bytes(&mut f);
                    f
                })
                .collect();
            let refs: Vec<&[u8]> = data.iter().map(|f| f.as_slice()).collect();
            let parity = code.encode(&refs).map_err(|e| e.to_string())?;
            let all: Vec<Vec<u8>> = data.iter().chain(parity.iter()).cloned().collect();
            // Keep only k-1 shards: reconstruction must be refused.
            let shards: Vec<(usize, &[u8])> =
                (0..k - 1).map(|i| (i, all[i].as_slice())).collect();
            match code.reconstruct(&shards) {
                Err(_) => Ok(()),
                Ok(_) => Err(format!("k={k} m={m}: reconstructed from k-1 shards")),
            }
        },
    );
}

// === Arena-native kernels (ISSUE 3) ===

#[test]
fn prop_encode_strided_matches_encode() {
    // The strided in-place encoder must produce byte-identical parity to
    // the Vec-based reference across random (k, m, stride) draws.
    check(
        &PropConfig { cases: 60, ..Default::default() },
        |rng| {
            let k = rng.range(1, 12);
            let m = rng.range(0, 8);
            let s = rng.range(1, 200);
            (k, m, s, rng.next_u64())
        },
        no_shrink,
        |&(k, m, s, seed)| {
            let mut rng = Pcg64::seeded(seed);
            let code = RsCode::new(k, m).map_err(|e| e.to_string())?;
            let data: Vec<Vec<u8>> = (0..k)
                .map(|_| {
                    let mut f = vec![0u8; s];
                    rng.fill_bytes(&mut f);
                    f
                })
                .collect();
            let refs: Vec<&[u8]> = data.iter().map(|f| f.as_slice()).collect();
            let parity = code.encode(&refs).map_err(|e| e.to_string())?;
            let mut buf = vec![0xDDu8; (k + m) * s]; // pre-dirtied
            for (i, d) in data.iter().enumerate() {
                buf[i * s..(i + 1) * s].copy_from_slice(d);
            }
            code.encode_strided(&mut buf, s).map_err(|e| e.to_string())?;
            for (p, want) in parity.iter().enumerate() {
                if buf[(k + p) * s..(k + p + 1) * s] != want[..] {
                    return Err(format!("parity {p} differs: k={k} m={m} s={s}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_reconstruct_into_matches_reconstruct() {
    // Arena-native decode must agree byte-for-byte with the Vec-based
    // reference across random loss patterns — and a second call with the
    // same pattern (cache hit) must return the identical bytes.
    check(
        &PropConfig { cases: 60, ..Default::default() },
        |rng| {
            let k = rng.range(1, 10);
            let m = rng.range(1, 7);
            (k, m, rng.next_u64())
        },
        no_shrink,
        |&(k, m, seed)| {
            let mut rng = Pcg64::seeded(seed);
            let s = 48;
            let mut code = RsCode::new(k, m).map_err(|e| e.to_string())?;
            let data: Vec<Vec<u8>> = (0..k)
                .map(|_| {
                    let mut f = vec![0u8; s];
                    rng.fill_bytes(&mut f);
                    f
                })
                .collect();
            let refs: Vec<&[u8]> = data.iter().map(|f| f.as_slice()).collect();
            let parity = code.encode(&refs).map_err(|e| e.to_string())?;
            let all: Vec<Vec<u8>> = data.iter().chain(parity.iter()).cloned().collect();
            // Drop up to m random fragments.
            let lose = rng.range(0, m + 1);
            let lost = rng.sample_indices(k + m, lose);
            let shards: Vec<(usize, &[u8])> = (0..k + m)
                .filter(|i| !lost.contains(i))
                .map(|i| (i, all[i].as_slice()))
                .collect();
            let want = code.reconstruct(&shards).map_err(|e| e.to_string())?;
            let flat: Vec<u8> = want.concat();
            let mut out = vec![0x55u8; k * s];
            for round in 0..2 {
                out.fill(0x55);
                code.reconstruct_into(&shards, &mut out).map_err(|e| e.to_string())?;
                if out != flat {
                    return Err(format!(
                        "mismatch k={k} m={m} lost={lost:?} round={round} (hit≠miss)"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn decode_matrix_cache_hits_across_groups_with_same_pattern() {
    // Thousands of FTGs losing the same fragments (a steady loss regime)
    // must invert the submatrix once.
    let (k, m, s) = (8usize, 3usize, 64usize);
    let mut code = RsCode::new(k, m).unwrap();
    let mut rng = Pcg64::seeded(0xCAFE);
    let lost = [2usize, 9];
    let mut out = vec![0u8; k * s];
    for _group in 0..50 {
        let data: Vec<Vec<u8>> = (0..k)
            .map(|_| {
                let mut f = vec![0u8; s];
                rng.fill_bytes(&mut f);
                f
            })
            .collect();
        let refs: Vec<&[u8]> = data.iter().map(|f| f.as_slice()).collect();
        let parity = code.encode(&refs).unwrap();
        let all: Vec<Vec<u8>> = data.iter().chain(parity.iter()).cloned().collect();
        let shards: Vec<(usize, &[u8])> = (0..k + m)
            .filter(|i| !lost.contains(i))
            .map(|i| (i, all[i].as_slice()))
            .collect();
        code.reconstruct_into(&shards, &mut out).unwrap();
        let flat: Vec<u8> = data.concat();
        assert_eq!(out, flat);
    }
    let (hits, misses) = code.decode_cache_stats();
    assert_eq!(misses, 1, "one inversion for 50 identically-lossy groups");
    assert_eq!(hits, 49);
}

// === Kernel tiers + coding pool (ISSUE 8) ===

#[test]
fn prop_slice_kernels_byte_identical_across_tiers() {
    // mul_slice / mul_slice_add on every supported tier must match the
    // scalar reference bit-for-bit: random constants (including 0 and 1
    // by density), odd lengths, lengths below one SIMD vector, and
    // misaligned starts (odd subslice offsets defeat any alignment
    // assumption in the 16/32-byte paths).
    check(
        &PropConfig { cases: 150, ..Default::default() },
        |rng| {
            let len = rng.range(0, 300);
            let off = rng.range(0, 5);
            let c = rng.next_below(256) as u8;
            (len, off, c, rng.next_u64())
        },
        no_shrink,
        |&(len, off, c, seed)| {
            let mut rng = Pcg64::seeded(seed);
            let t = gf256::MulTable::new(c);
            let mut x = vec![0u8; off + len];
            rng.fill_bytes(&mut x);
            let mut y0 = vec![0u8; off + len];
            rng.fill_bytes(&mut y0);
            for &tier in &kernel::supported_tiers() {
                let mut got = y0.clone();
                let mut want = y0.clone();
                t.mul_slice_tier(&x[off..], &mut got[off..], tier);
                t.mul_slice_tier(&x[off..], &mut want[off..], KernelTier::Scalar);
                if got != want {
                    return Err(format!("mul_slice {tier} ≠ scalar: c={c} len={len} off={off}"));
                }
                let mut got = y0.clone();
                let mut want = y0.clone();
                t.mul_slice_add_tier(&x[off..], &mut got[off..], tier);
                t.mul_slice_add_tier(&x[off..], &mut want[off..], KernelTier::Scalar);
                if got != want {
                    return Err(format!(
                        "mul_slice_add {tier} ≠ scalar: c={c} len={len} off={off}"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn slice_kernel_edge_lengths_and_constants_across_tiers() {
    // Deterministic sweep of the boundary cases the prop may under-
    // sample: the zero and identity constants, and every length around
    // the 16-byte (SSSE3) and 32-byte (AVX2) vector widths.
    let lens = [0usize, 1, 7, 15, 16, 17, 31, 32, 33, 63, 64, 65, 100];
    let mut rng = Pcg64::seeded(0x1551);
    for &c in &[0u8, 1, 2, 0x1D, 255] {
        let t = gf256::MulTable::new(c);
        for &len in &lens {
            for off in 0..3usize {
                let mut x = vec![0u8; off + len];
                rng.fill_bytes(&mut x);
                let mut y0 = vec![0u8; off + len];
                rng.fill_bytes(&mut y0);
                for &tier in &kernel::supported_tiers() {
                    let mut got = y0.clone();
                    let mut want = y0.clone();
                    t.mul_slice_add_tier(&x[off..], &mut got[off..], tier);
                    t.mul_slice_add_tier(&x[off..], &mut want[off..], KernelTier::Scalar);
                    assert_eq!(got, want, "add c={c} len={len} off={off} tier={tier}");
                    let mut got = y0.clone();
                    let mut want = y0.clone();
                    t.mul_slice_tier(&x[off..], &mut got[off..], tier);
                    t.mul_slice_tier(&x[off..], &mut want[off..], KernelTier::Scalar);
                    assert_eq!(got, want, "set c={c} len={len} off={off} tier={tier}");
                }
            }
        }
    }
}

#[test]
fn prop_fused_encode_matches_rowwise_on_every_tier() {
    // The fused multi-row strided encode must equal the row-at-a-time
    // scalar reference byte-for-byte on every tier, across odd strides
    // (including strides under one SIMD vector) and parity counts that
    // exercise partial bands. Parity slots are pre-dirtied: write-once
    // semantics must fully overwrite them.
    check(
        &PropConfig { cases: 80, ..Default::default() },
        |rng| {
            let k = rng.range(1, 14);
            let m = rng.range(0, 10);
            let s = rng.range(1, 90);
            (k, m, s, rng.next_u64())
        },
        no_shrink,
        |&(k, m, s, seed)| {
            let mut rng = Pcg64::seeded(seed);
            let code = RsCode::new(k, m).map_err(|e| e.to_string())?;
            let mut base = vec![0u8; (k + m) * s];
            rng.fill_bytes(&mut base[..k * s]);
            let mut want = base.clone();
            code.encode_strided_rowwise(&mut want, s, KernelTier::Scalar)
                .map_err(|e| e.to_string())?;
            for &tier in &kernel::supported_tiers() {
                let mut fused = base.clone();
                fused[k * s..].fill(0xEE);
                code.encode_strided_tier(&mut fused, s, tier).map_err(|e| e.to_string())?;
                if fused != want {
                    return Err(format!("fused {tier} ≠ scalar rowwise: k={k} m={m} s={s}"));
                }
                let mut row = base.clone();
                row[k * s..].fill(0xEE);
                code.encode_strided_rowwise(&mut row, s, tier).map_err(|e| e.to_string())?;
                if row != want {
                    return Err(format!("rowwise {tier} ≠ scalar rowwise: k={k} m={m} s={s}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn encode_batch_matches_sequential_for_any_worker_count() {
    // The pool's determinism contract, asserted end-to-end: a batch of
    // arenas encoded through 0/1/2/8 workers is byte-identical to
    // sequential `encode_parity` in order.
    let (k, m, s) = (9usize, 4usize, 96usize);
    let code = RsCode::new(k, m).unwrap();
    let mut rng = Pcg64::seeded(0xBA7C);
    let base: Vec<Vec<u8>> = (0..12)
        .map(|_| {
            let mut v = vec![0u8; k * s];
            rng.fill_bytes(&mut v);
            v
        })
        .collect();
    let build = |data: &[Vec<u8>]| -> Vec<FtgArena> {
        data.iter()
            .map(|d| {
                let mut a = FtgArena::new(k as u8, m as u8, s);
                a.as_mut_slice()[..k * s].copy_from_slice(d);
                a
            })
            .collect()
    };
    let mut seq = build(&base);
    for a in seq.iter_mut() {
        a.encode_parity(&code).unwrap();
    }
    for workers in [0usize, 1, 2, 8] {
        let pool = CodingPool::new(workers);
        let mut arenas = build(&base);
        code.encode_batch(&pool, &mut arenas).unwrap();
        for (i, (got, want)) in arenas.iter().zip(seq.iter()).enumerate() {
            assert_eq!(got.as_slice(), want.as_slice(), "arena {i} workers={workers}");
            assert_eq!(got.have_total(), k + m, "presence marks, arena {i}");
        }
    }
}

#[test]
fn reconstruct_batch_matches_sequential_for_any_worker_count() {
    // Decode side of the determinism contract: batches of lossy groups
    // reconstructed through 0/1/2/8 workers equal per-group
    // `reconstruct_into`, and per-item errors land in order.
    let (k, m, s) = (8usize, 3usize, 64usize);
    let mut code = RsCode::new(k, m).unwrap();
    let mut rng = Pcg64::seeded(0xDECBA);
    // Build 10 encoded groups, each missing a different fragment pair.
    let mut lossy: Vec<FtgArena> = Vec::new();
    let mut want: Vec<Vec<u8>> = Vec::new();
    for g in 0..10usize {
        let mut full = FtgArena::new(k as u8, m as u8, s);
        let mut data = vec![0u8; k * s];
        rng.fill_bytes(&mut data);
        full.as_mut_slice()[..k * s].copy_from_slice(&data);
        full.encode_parity(&code).unwrap();
        let lost = [g % (k + m), (g * 5 + 1) % (k + m)];
        let mut partial = FtgArena::new(k as u8, m as u8, s);
        for idx in 0..k + m {
            if !lost.contains(&idx) {
                assert!(partial.insert(idx, full.slot(idx)));
            }
        }
        let shards: Vec<(usize, &[u8])> = partial.iter_present().collect();
        let mut out = vec![0u8; k * s];
        code.reconstruct_into(&shards, &mut out).unwrap();
        assert_eq!(out, data, "group {g} reference decode");
        lossy.push(partial);
        want.push(out);
    }
    // One undecodable group at the end: its error must come back in
    // position without disturbing the others.
    let starved = FtgArena::new(k as u8, m as u8, s);
    lossy.push(starved);
    for workers in [0usize, 1, 2, 8] {
        let pool = CodingPool::new(workers);
        let mut outs = vec![vec![0xA5u8; k * s]; lossy.len()];
        let mut items: Vec<(&FtgArena, &mut [u8])> = lossy
            .iter()
            .zip(outs.iter_mut())
            .map(|(a, o)| (a, o.as_mut_slice()))
            .collect();
        let results = code.reconstruct_batch(&pool, &mut items);
        assert_eq!(results.len(), lossy.len());
        for (g, w) in want.iter().enumerate() {
            assert!(results[g].is_ok(), "group {g} workers={workers}");
            assert_eq!(&outs[g], w, "group {g} workers={workers}");
        }
        assert!(results[want.len()].is_err(), "starved group must error");
    }
}

// === GF(2^8) field axioms ===

#[test]
fn prop_gf256_field_axioms() {
    check(
        &PropConfig { cases: 512, ..Default::default() },
        |rng| {
            (
                rng.next_below(256) as u8,
                rng.next_below(256) as u8,
                rng.next_below(256) as u8,
            )
        },
        no_shrink,
        |&(a, b, c)| {
            // Commutativity.
            if gf256::add(a, b) != gf256::add(b, a) {
                return Err(format!("add not commutative: {a} {b}"));
            }
            if gf256::mul(a, b) != gf256::mul(b, a) {
                return Err(format!("mul not commutative: {a} {b}"));
            }
            // Associativity.
            if gf256::add(gf256::add(a, b), c) != gf256::add(a, gf256::add(b, c)) {
                return Err(format!("add not associative: {a} {b} {c}"));
            }
            if gf256::mul(gf256::mul(a, b), c) != gf256::mul(a, gf256::mul(b, c)) {
                return Err(format!("mul not associative: {a} {b} {c}"));
            }
            // Distributivity.
            if gf256::mul(a, gf256::add(b, c))
                != gf256::add(gf256::mul(a, b), gf256::mul(a, c))
            {
                return Err(format!("not distributive: {a} {b} {c}"));
            }
            // Identities and inverses.
            if gf256::add(a, 0) != a || gf256::mul(a, 1) != a {
                return Err(format!("identity broken at {a}"));
            }
            if gf256::add(a, a) != 0 {
                return Err(format!("additive inverse broken at {a}"));
            }
            if a != 0 {
                let inv = gf256::inv(a);
                if gf256::mul(a, inv) != 1 {
                    return Err(format!("multiplicative inverse broken at {a}"));
                }
                if gf256::div(b, a) != gf256::mul(b, inv) {
                    return Err(format!("div inconsistent at {b}/{a}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn gf256_mul_associativity_exhaustive_on_stride() {
    // Deterministic lattice sweep complements the random prop: every
    // (a, b, c) on a stride-5/7/11 grid (~110k triples).
    for a in (0..=255u16).step_by(5) {
        for b in (0..=255u16).step_by(7) {
            for c in (0..=255u16).step_by(11) {
                let (a, b, c) = (a as u8, b as u8, c as u8);
                assert_eq!(
                    gf256::mul(gf256::mul(a, b), c),
                    gf256::mul(a, gf256::mul(b, c)),
                    "({a}·{b})·{c} ≠ {a}·({b}·{c})"
                );
            }
        }
    }
}

#[test]
fn gf256_every_nonzero_element_has_unique_inverse() {
    let mut seen = [false; 256];
    for a in 1..=255u8 {
        let inv = gf256::inv(a);
        assert_eq!(gf256::mul(a, inv), 1, "a={a}");
        assert!(!seen[inv as usize] || inv == a && a == 1, "inverse collision at {a}");
        seen[inv as usize] = true;
    }
    assert!(!seen[0], "zero can never be an inverse");
}
