//! End-to-end acceptance matrix for the adaptive layer: congestion-type
//! loss must make the controller back the pacing rate off, while
//! Gilbert-Elliott burst loss at the *same mean λ* must sustain the rate
//! and buy parity instead — bit-identical across runs on the virtual
//! clock. Also the satellite regression for whole-pass-0 loss with the
//! frozen first-pass FTG geometry.

use janus::api::{
    run_pair, AdaptConfig, Contract, Dataset, FnObserver, StagedTransport, TransferEvent,
    TransferReport, TransferSpec,
};
use janus::coordinator::PacketView;
use janus::model::NetParams;
use janus::testkit::{
    congestion_transport_pair, loss_transport_pair, tcp_competitor_transport_pair, LossTrace,
};
use janus::transport::channel::Datagram;
use janus::util::Pcg64;
use std::time::Duration;

const STREAMS: usize = 4;
const RATE: f64 = 200_000.0;

fn sized_dataset(seed: u64, scale: usize) -> Dataset {
    let mut rng = Pcg64::seeded(seed);
    let sizes = [60_000usize * scale, 250_000 * scale, 500_000 * scale];
    let eps = vec![0.004, 0.0005, 0.0000001];
    Dataset::new(
        sizes
            .iter()
            .map(|&sz| {
                let mut v = vec![0u8; sz];
                rng.fill_bytes(&mut v);
                v
            })
            .collect(),
        eps,
    )
    .unwrap()
}

fn spec(initial_lambda: f64, streams: usize, adapt: AdaptConfig) -> TransferSpec {
    TransferSpec::builder()
        .contract(Contract::Fidelity(1e-7))
        .streams(streams)
        .net(NetParams { t: 0.0005, r: RATE, lambda: 0.0, n: 32, s: 1024 })
        .initial_lambda(initial_lambda)
        .lambda_window(0.25)
        .idle_timeout(Duration::from_secs(10))
        .max_duration(Duration::from_secs(120))
        .adaptation(adapt)
        .build()
        .unwrap()
}

fn assert_byte_exact(report: &TransferReport, data: &Dataset) {
    for (li, (got, want)) in report.received.levels.iter().zip(&data.levels).enumerate() {
        assert_eq!(
            got.as_ref().expect("level must be delivered"),
            want,
            "level {li} bytes differ"
        );
    }
    assert_eq!(report.received.levels_recovered, data.levels.len());
}

/// Run the pooled engine through the rate-responsive congestion channel:
/// a sender-side observer closes the loop by applying each `RateAdapted`
/// rate to the channel's policer before the next pass fans out.
fn run_congested(capacity: f64, data: &Dataset) -> TransferReport {
    let (sender_t, receiver_t, handle) = congestion_transport_pair(STREAMS, capacity, RATE);
    let h = handle.clone();
    let mut obs = FnObserver(move |e: &TransferEvent| {
        if let TransferEvent::RateAdapted { rate, .. } = e {
            h.set(*rate);
        }
    });
    let report = run_pair(
        &spec(0.0, STREAMS, AdaptConfig::default()),
        sender_t,
        receiver_t,
        data,
        Some(&mut obs),
        None,
    )
    .unwrap();
    assert_byte_exact(&report, data);
    report
}

#[test]
fn congestion_loss_backs_the_rate_off_and_still_delivers() {
    let data = sized_dataset(0xC0DE, 1);
    let capacity = 0.5 * RATE; // policer admits half the nominal rate
    let rep = run_congested(capacity, &data);

    let rates = &rep.sent.rate_history;
    assert!(!rates.is_empty(), "congested run must cross pass barriers");
    let min = rates.iter().cloned().fold(f64::INFINITY, f64::min);
    assert!(
        min < 0.6 * RATE,
        "policer at {capacity} should force a real back-off, min rate {min}"
    );
    assert!(
        min >= 0.25 * RATE - 1e-9,
        "back-off must respect the configured rate floor, min rate {min}"
    );
    assert!(
        *rates.last().unwrap() <= RATE,
        "rate can never exceed the configured maximum"
    );
    // The verdict history is part of the trace: at least one barrier
    // settled below nominal.
    let trace = rep.sent.trace().unwrap();
    assert!(trace.iter().any(|p| p.rate < RATE), "trace must record the back-off");
}

#[test]
fn congested_runs_are_bit_identical() {
    // Same policer, same dataset: the closed loop (observer → RateHandle
    // → token bucket keyed on fragment ordinals → barrier statistics →
    // controller on the virtual clock) must replay exactly.
    let data = sized_dataset(0xC0DE, 1);
    let a = run_congested(0.5 * RATE, &data);
    let b = run_congested(0.5 * RATE, &data);
    assert_eq!(a.sent.rate_history, b.sent.rate_history);
    assert_eq!(a.sent.lambda_history, b.sent.lambda_history);
    assert_eq!(a.sent.trace().unwrap(), b.sent.trace().unwrap());
    assert_eq!(a.sent.passes, b.sent.passes);
}

#[test]
fn tcp_competitor_shares_the_link_without_starvation() {
    // A Reno flow (ACK-clocked, so it reacts far faster than the
    // pass-barrier controller) shares every data stream's link with the
    // janus sender. Neither side may starve: the controller's rate floor
    // keeps janus sending, and its back-off leaves room for the
    // competitor's sawtooth.
    let data = sized_dataset(0x7C9, 3);
    let (sender_t, receiver_t, handle, stats) =
        tcp_competitor_transport_pair(STREAMS, RATE, RATE, 5e-4);
    let h = handle.clone();
    let mut obs = FnObserver(move |e: &TransferEvent| {
        if let TransferEvent::RateAdapted { rate, .. } = e {
            h.set(*rate);
        }
    });
    let report = run_pair(
        &spec(0.0, STREAMS, AdaptConfig::default()),
        sender_t,
        receiver_t,
        &data,
        Some(&mut obs),
        None,
    )
    .unwrap();
    assert_byte_exact(&report, &data);

    // Janus is never throttled below its configured floor, nor above max.
    let rates = &report.sent.rate_history;
    assert!(!rates.is_empty(), "competition must cross pass barriers");
    let min = rates.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = rates.iter().cloned().fold(0.0, f64::max);
    assert!(min >= 0.25 * RATE - 1e-9, "rate floor violated: {min}");
    assert!(max <= RATE + 1e-9, "rate ceiling violated: {max}");

    // Both flows land a real share of the link's grants.
    let janus_through = stats.janus_offered() - stats.janus_dropped();
    let total = janus_through + stats.tcp_sent();
    let janus_share = janus_through as f64 / total as f64;
    let tcp_share = stats.tcp_sent() as f64 / total as f64;
    assert!(janus_share >= 0.10, "janus starved by TCP: share {janus_share}");
    assert!(tcp_share >= 0.10, "TCP starved by janus: share {tcp_share}");
    // …and TCP is genuinely regulated by the shared link, not free-riding
    // on an idle one.
    assert!(stats.tcp_dropped() > 0, "Reno never hit the shared bucket");
}

fn run_ge(adapt: AdaptConfig, seed: u64, scale: usize) -> TransferReport {
    let data = sized_dataset(0xDA7A ^ seed, scale);
    let transports = loss_transport_pair(STREAMS, |w| {
        LossTrace::gilbert_elliott(0.2, 8.0, RATE, seed ^ (w as u64 + 1) * 0x9E37)
    });
    let (sender_t, receiver_t) = transports;
    let report = run_pair(
        &spec(0.2 * RATE * STREAMS as f64, STREAMS, adapt),
        sender_t,
        receiver_t,
        &data,
        None,
        None,
    )
    .unwrap();
    assert_byte_exact(&report, &data);
    report
}

#[test]
fn ge_burst_loss_sustains_rate_where_congestion_loss_backs_off() {
    // The discrimination matrix of the adaptive layer: 20% mean loss in
    // 8-fragment bursts is *channel* loss — rate stays at (or within one
    // probe of) nominal and the solver buys parity instead. The policer
    // scenario above, at a comparable mean loss, collapses the rate.
    let ge = run_ge(AdaptConfig::default(), 55, 1);
    let rates = &ge.sent.rate_history;
    assert!(!rates.is_empty());
    let min_ge = rates.iter().cloned().fold(f64::INFINITY, f64::min);
    assert!(
        min_ge >= 0.69 * RATE,
        "burst loss must never be mistaken for congestion: min rate {min_ge}"
    );

    let trace = ge.sent.trace().unwrap();
    assert!(
        trace.iter().any(|p| p.burst > 3.0),
        "the two-state estimator must see the bursts: {:?}",
        trace.iter().map(|p| p.burst).collect::<Vec<_>>()
    );

    let congested = run_congested(0.5 * RATE, &sized_dataset(0xC0DE, 1));
    let min_cong =
        congested.sent.rate_history.iter().cloned().fold(f64::INFINITY, f64::min);
    assert!(
        min_cong < min_ge,
        "congestion ({min_cong}) must back off further than burst loss ({min_ge})"
    );
}

#[test]
fn burst_aware_solver_outpaces_the_iid_baseline_on_ge_loss() {
    // Same GE traces, same mean λ̂: the i.i.d. Eq. 8 solve sits on the
    // plateau where any m below one extra burst leaves the group-failure
    // probability unchanged, so the burst-aware solve (Eq. 2 on loss
    // *events* plus the burst parity floor) drains the lost-FTG list in
    // strictly fewer passes.
    let adaptive = run_ge(AdaptConfig::default(), 77, 3);
    let baseline = run_ge(AdaptConfig::fixed(), 77, 3);
    assert!(
        adaptive.sent.passes < baseline.sent.passes,
        "burst-aware {} passes vs iid {} passes",
        adaptive.sent.passes,
        baseline.sent.passes
    );
    let max_m = adaptive.sent.trace().unwrap().iter().map(|p| p.m).max().unwrap();
    assert!(
        max_m >= 12,
        "burst floor should push parity past the plateau, max m {max_m}"
    );
    // Determinism rider: the adaptive run replays bit-identically.
    let again = run_ge(AdaptConfig::default(), 77, 3);
    assert_eq!(adaptive.sent.trace().unwrap(), again.sent.trace().unwrap());
}

#[test]
fn fixed_config_reports_a_constant_rate() {
    let data = sized_dataset(0xF1DE, 1);
    let transports =
        loss_transport_pair(STREAMS, |w| LossTrace::seeded(0.05, 0x5EED ^ (w as u64 + 1)));
    let (sender_t, receiver_t) = transports;
    let rep = run_pair(
        &spec(0.05 * RATE * STREAMS as f64, STREAMS, AdaptConfig::fixed()),
        sender_t,
        receiver_t,
        &data,
        None,
        None,
    )
    .unwrap();
    assert_byte_exact(&rep, &data);
    assert!(
        rep.sent.rate_history.iter().all(|r| *r == RATE),
        "fixed() must never move the rate: {:?}",
        rep.sent.rate_history
    );
}

/// Control-channel wrapper that eats every pass-0 fragment (control
/// packets and retransmissions pass through) — the whole-first-pass-loss
/// scenario for the single-stream engine.
struct DropPass0<C: Datagram>(C);

impl<C: Datagram> Datagram for DropPass0<C> {
    fn send(&mut self, buf: &[u8]) {
        if let Ok(PacketView::Fragment(v)) = PacketView::decode(buf) {
            if v.header.pass == 0 {
                return;
            }
        }
        self.0.send(buf);
    }

    fn recv_into(&mut self, buf: &mut [u8], timeout: Duration) -> Option<usize> {
        self.0.recv_into(buf, timeout)
    }

    fn try_recv_into(&mut self, buf: &mut [u8]) -> Option<usize> {
        self.0.try_recv_into(buf)
    }
}

#[test]
fn full_pass0_loss_recovers_in_one_retransmission_pass() {
    // Regression for the lost-FTG enumeration of groups the receiver
    // never saw: with the manifest's frozen pass-0 parity, every level
    // walks its true k₀·s stride, so one barrier enumerates *all* lost
    // groups and one retransmission pass (lossless here) delivers them.
    // The old worst-case n·s stride under-enumerated and needed extra
    // feedback rounds.
    let data = sized_dataset(0xBAD0, 1);
    let (sc, rc) = janus::transport::channel::mem_pair();
    let sender_t = StagedTransport::new(DropPass0(sc), Vec::new());
    let receiver_t = StagedTransport::new(rc, Vec::new());
    let rep = run_pair(
        // λ₀ > 0 so pass 0 plans real parity: k₀ = n − m₀ < n, the
        // geometry the buggy stride guessed wrong.
        &spec(0.05 * RATE, 1, AdaptConfig::fixed()),
        sender_t,
        receiver_t,
        &data,
        None,
        None,
    )
    .unwrap();
    assert_byte_exact(&rep, &data);
    assert_eq!(
        rep.sent.passes, 1,
        "complete loss enumeration ⇒ exactly one retransmission pass"
    );
}
