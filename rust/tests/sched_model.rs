//! Bounded-interleaving model checks for the crate's three hand-rolled
//! concurrency structures (DESIGN.md §13): the coding-pool batch latch
//! (`erasure::par`), the serve daemon's generation-fenced completion
//! queue (`serve::Daemon::drain_completions`), and the transport
//! `FrameQueue` close/drain protocol. Each structure is mirrored onto
//! `testkit::sched` shims *in its real shape* — same lock boundaries,
//! same check order — and explored exhaustively up to a preemption
//! bound. Each mirror is also mutation-tested: a seeded concurrency bug
//! (lost-update latch, off-by-one generation fence, closed-check before
//! drain, close without the lock) must produce a finding, or the model
//! would prove nothing.

use janus::testkit::sched::{explore, Config, Env, Finding};
use std::sync::atomic::Ordering;

// ---------------------------------------------------------------------------
// 1. erasure::par — batch latch + submitter-helps-drain
// ---------------------------------------------------------------------------

/// Mirror of `CodingPool::run_batch` with one worker: the submitter
/// enqueues two jobs, then drains the queue itself before waiting on
/// the latch, while a worker thread concurrently pops jobs. The latch
/// is the exact `par::Latch` shape: `Mutex<(outstanding, poisoned)>` +
/// condvar, `notify_all` at zero, predicate-looped wait. A "panicking"
/// job completes with `ok = false` (the real code's `catch_unwind`).
fn pool_batch_scenario(env: &mut Env, poison: bool) {
    let queue = env.mutex(vec![0usize, 1]);
    let latch = env.mutex((2usize, false));
    let latch_cv = env.condvar();
    let executed = env.atomic_usize(0);
    let waited = env.atomic_usize(usize::MAX);

    let complete = {
        let latch = latch.clone();
        let latch_cv = latch_cv.clone();
        move |ok: bool| {
            let mut st = latch.lock();
            st.0 -= 1;
            if !ok {
                st.1 = true;
            }
            if st.0 == 0 {
                latch_cv.notify_all();
            }
        }
    };

    // Worker: pop until the queue is empty, then exit (a worker that
    // never gets scheduled is the zero-worker pool — the submitter
    // still finishes the batch alone).
    {
        let queue = queue.clone();
        let executed = executed.clone();
        let complete = complete.clone();
        env.spawn(move || loop {
            let job = queue.lock().pop();
            match job {
                Some(j) => {
                    executed.fetch_add(1, Ordering::SeqCst);
                    complete(!(poison && j == 0));
                }
                None => break,
            }
        });
    }

    // Submitter: help drain, then wait the latch.
    {
        let queue = queue.clone();
        let executed = executed.clone();
        let latch = latch.clone();
        let latch_cv = latch_cv.clone();
        let waited = waited.clone();
        env.spawn(move || {
            loop {
                let job = queue.lock().pop();
                match job {
                    Some(j) => {
                        executed.fetch_add(1, Ordering::SeqCst);
                        complete(!(poison && j == 0));
                    }
                    None => break,
                }
            }
            let mut st = latch.lock();
            while st.0 > 0 {
                st = latch_cv.wait(st);
            }
            waited.store(usize::from(st.1), Ordering::SeqCst);
        });
    }

    let want = usize::from(poison);
    env.finally(move || {
        assert_eq!(executed.load(Ordering::SeqCst), 2, "every job ran exactly once");
        assert_eq!(
            waited.load(Ordering::SeqCst),
            want,
            "wait() must report poisoning iff a job panicked"
        );
    });
}

#[test]
fn coding_pool_batch_completes_in_every_interleaving() {
    let report = explore(&Config::with_bound(2), |env| pool_batch_scenario(env, false));
    report.assert_ok();
    assert!(report.exhausted, "bounded space must be fully enumerated");
    assert!(report.schedules > 1, "the mirror must actually branch");
}

#[test]
fn coding_pool_poisoning_reaches_the_submitter_in_every_interleaving() {
    let report = explore(&Config::with_bound(2), |env| pool_batch_scenario(env, true));
    report.assert_ok();
    assert!(report.exhausted);
}

/// Seeded bug: the outstanding-job count kept in a bare atomic with a
/// load/store (non-atomic) decrement instead of under the latch mutex.
/// Two completers can both read 2 and both write 1 — the count never
/// hits zero, nobody notifies, and the waiter blocks forever. The
/// checker must find the lost update as a deadlock.
#[test]
fn broken_latch_lost_update_is_caught() {
    let report = explore(&Config::with_bound(2), |env| {
        let count = env.atomic_usize(2);
        let gate = env.mutex(());
        let cv = env.condvar();
        for _ in 0..2 {
            let count = count.clone();
            let gate = gate.clone();
            let cv = cv.clone();
            env.spawn(move || {
                let c = count.load(Ordering::SeqCst);
                count.store(c - 1, Ordering::SeqCst);
                if c - 1 == 0 {
                    let _g = gate.lock();
                    cv.notify_all();
                }
            });
        }
        {
            let count = count.clone();
            let gate = gate.clone();
            let cv = cv.clone();
            env.spawn(move || {
                let mut g = gate.lock();
                while count.load(Ordering::SeqCst) > 0 {
                    g = cv.wait(g);
                }
                drop(g);
            });
        }
    });
    let failure = report.assert_finding();
    assert!(
        matches!(&failure.finding, Finding::Deadlock { blocked } if blocked == &[2]),
        "expected the waiter deadlocked, got {:?}",
        failure.finding
    );
}

// ---------------------------------------------------------------------------
// 2. serve — generation-fenced coding completions
// ---------------------------------------------------------------------------

/// Mirror of `Daemon::drain_completions` against a slot that is reaped
/// and reused while an old tenant's coding job is still in flight. The
/// worker pushes a completion stamped with generation 0; the daemon
/// bumps the slot to generation 1 (new tenant) and then drains,
/// delivering a completion only when its stamp equals the slot's
/// current generation. `fence_slack` widens the acceptance window — 0
/// is the real code, 1 is the seeded off-by-one that hands the new
/// tenant the dead tenant's job.
fn gen_fence_scenario(env: &mut Env, fence_slack: usize) {
    let completions = env.mutex(Vec::<(usize, u32)>::new());
    let slot_gen = env.atomic_usize(0);
    let stale_delivered = env.atomic_bool(false);

    // Coding worker: finish the generation-0 tenant's job.
    {
        let completions = completions.clone();
        env.spawn(move || {
            completions.lock().push((0, 7));
        });
    }

    // Daemon: reap + reuse the slot, then drain completions.
    {
        let completions = completions.clone();
        let slot_gen = slot_gen.clone();
        let stale = stale_delivered.clone();
        env.spawn(move || {
            slot_gen.store(1, Ordering::SeqCst);
            let done = std::mem::take(&mut *completions.lock());
            for (gen, _payload) in done {
                let cur = slot_gen.load(Ordering::SeqCst);
                let deliver = cur == gen || (fence_slack > 0 && cur == gen + fence_slack);
                if deliver && gen != cur {
                    stale.store(true, Ordering::SeqCst);
                }
            }
        });
    }

    env.finally(move || {
        assert!(
            !stale_delivered.load(Ordering::SeqCst),
            "a stale-generation completion was delivered to the slot's new occupant"
        );
    });
}

#[test]
fn generation_fence_never_delivers_stale_completions() {
    let report = explore(&Config::with_bound(2), |env| gen_fence_scenario(env, 0));
    report.assert_ok();
    assert!(report.exhausted);
    assert!(report.schedules > 1);
}

#[test]
fn off_by_one_generation_fence_is_caught() {
    let report = explore(&Config::with_bound(2), |env| gen_fence_scenario(env, 1));
    let failure = report.assert_finding();
    assert!(
        matches!(&failure.finding, Finding::Check { message } if message.contains("stale")),
        "expected the stale-delivery post-condition to fire, got {:?}",
        failure.finding
    );
}

// ---------------------------------------------------------------------------
// 3. transport — FrameQueue close/drain protocol
// ---------------------------------------------------------------------------

/// Mirror of `FrameQueue` (`transport::channel`): producer pushes a
/// backlog then closes; the consumer loops `pop_timeout`'s exact check
/// order — drain first, closed second, wait third. One deliberate
/// difference: the real `close()` stores the flag without taking the
/// queue lock and relies on `pop_timeout`'s *bounded* wait to cover the
/// check-to-wait window; the model has no timeouts, so the mirror
/// stores the flag under the lock (the equivalent protocol).
/// `naked_close_without_the_lock_deadlocks` below checks the real
/// variant and proves the window exists — documenting exactly why
/// `pop_timeout` must use `wait_timeout`, not `wait`.
fn frame_queue_scenario(env: &mut Env, buggy_check_order: bool) {
    let q = env.mutex(std::collections::VecDeque::<u32>::new());
    let cv = env.condvar();
    let closed = env.atomic_bool(false);
    let received = env.mutex(Vec::<u32>::new());

    {
        let q = q.clone();
        let cv = cv.clone();
        let closed = closed.clone();
        env.spawn(move || {
            for v in [1u32, 2] {
                q.lock().push_back(v);
                cv.notify_one();
            }
            {
                let _g = q.lock();
                closed.store(true, Ordering::SeqCst);
            }
            cv.notify_all();
        });
    }

    {
        let q = q.clone();
        let cv = cv.clone();
        let closed = closed.clone();
        let received = received.clone();
        env.spawn(move || {
            let mut g = q.lock();
            loop {
                if buggy_check_order {
                    // Seeded bug: report disconnection before draining —
                    // the backlog a finished sender left behind is lost.
                    if closed.load(Ordering::SeqCst) {
                        break;
                    }
                }
                if let Some(v) = g.pop_front() {
                    drop(g);
                    received.lock().push(v);
                    g = q.lock();
                    continue;
                }
                if closed.load(Ordering::SeqCst) {
                    break;
                }
                g = cv.wait(g);
            }
        });
    }

    env.finally(move || {
        assert_eq!(
            *received.lock(),
            vec![1, 2],
            "the backlog must deliver, in order, before the close is reported"
        );
    });
}

#[test]
fn frame_queue_backlog_survives_close_in_every_interleaving() {
    let report = explore(&Config::with_bound(2), |env| frame_queue_scenario(env, false));
    report.assert_ok();
    assert!(report.exhausted);
    assert!(report.schedules > 1);
}

#[test]
fn closed_check_before_drain_loses_the_backlog_and_is_caught() {
    let report = explore(&Config::with_bound(2), |env| frame_queue_scenario(env, true));
    let failure = report.assert_finding();
    assert!(
        matches!(failure.finding, Finding::Check { .. }),
        "expected the delivery post-condition to fire, got {:?}",
        failure.finding
    );
}

/// The real `close()` window, modeled honestly: flag stored without the
/// queue lock, consumer waiting unboundedly. The consumer can check
/// `closed` (false), the closer can store + notify while nobody waits,
/// and the consumer then sleeps forever. This is the latent lost-wakeup
/// that `pop_timeout`'s `wait_timeout` backstop absorbs in production —
/// the model check pins it so nobody "simplifies" the timeout away.
#[test]
fn naked_close_without_the_lock_deadlocks() {
    let report = explore(&Config::with_bound(1), |env| {
        let q = env.mutex(std::collections::VecDeque::<u32>::new());
        let cv = env.condvar();
        let closed = env.atomic_bool(false);
        {
            let closed = closed.clone();
            let cv = cv.clone();
            env.spawn(move || {
                closed.store(true, Ordering::SeqCst);
                cv.notify_all();
            });
        }
        {
            let q = q.clone();
            let cv = cv.clone();
            let closed = closed.clone();
            env.spawn(move || {
                let mut g = q.lock();
                loop {
                    if g.pop_front().is_some() {
                        continue;
                    }
                    if closed.load(Ordering::SeqCst) {
                        break;
                    }
                    g = cv.wait(g);
                }
            });
        }
    });
    let failure = report.assert_finding();
    assert!(
        matches!(&failure.finding, Finding::Deadlock { blocked } if blocked == &[1]),
        "expected the consumer asleep forever, got {:?}",
        failure.finding
    );
}

/// MemChannel drop semantics on top of the queue: a send that observed
/// the close drops its frame by choice; a send that raced past the
/// check may land after the consumer drained and left, in which case
/// the frame strands in the queue and is recycled when the queue drops
/// — also a drop, just a later one. What the protocol *does* guarantee,
/// in every interleaving: the pre-close backlog always delivers, and
/// nothing is ever delivered that was not pushed.
#[test]
fn racing_sender_frame_is_delivered_or_dropped_never_fabricated() {
    let report = explore(&Config::with_bound(1), |env| {
        let q = env.mutex(std::collections::VecDeque::<u32>::new());
        let cv = env.condvar();
        let closed = env.atomic_bool(false);
        let pushed9 = env.atomic_bool(false);
        let received = env.mutex(Vec::<u32>::new());

        // Tenant A: one frame, then close (endpoint drop).
        {
            let q = q.clone();
            let cv = cv.clone();
            let closed = closed.clone();
            env.spawn(move || {
                q.lock().push_back(1);
                cv.notify_one();
                {
                    let _g = q.lock();
                    closed.store(true, Ordering::SeqCst);
                }
                cv.notify_all();
            });
        }
        // Peer sender: MemChannel::send's exact shape — check closed,
        // then lease + push. The check and the push are separate steps,
        // so a close can land in between; that frame must still arrive.
        {
            let q = q.clone();
            let cv = cv.clone();
            let closed = closed.clone();
            let pushed9 = pushed9.clone();
            env.spawn(move || {
                if !closed.load(Ordering::SeqCst) {
                    q.lock().push_back(9);
                    cv.notify_one();
                    pushed9.store(true, Ordering::SeqCst);
                }
            });
        }
        // Consumer: drain-first close protocol.
        {
            let q = q.clone();
            let cv = cv.clone();
            let closed = closed.clone();
            let received = received.clone();
            env.spawn(move || {
                let mut g = q.lock();
                loop {
                    if let Some(v) = g.pop_front() {
                        drop(g);
                        received.lock().push(v);
                        g = q.lock();
                        continue;
                    }
                    if closed.load(Ordering::SeqCst) {
                        break;
                    }
                    g = cv.wait(g);
                }
            });
        }

        env.finally(move || {
            let got = received.lock();
            assert!(got.contains(&1), "the pre-close frame must always deliver: {got:?}");
            assert!(
                !got.contains(&9) || pushed9.load(Ordering::SeqCst),
                "a frame the sender dropped at the closed check cannot arrive: {got:?}"
            );
            assert!(got.iter().all(|v| *v == 1 || *v == 9), "fabricated frame: {got:?}");
        });
    });
    report.assert_ok();
    assert!(report.exhausted);
}

// ---------------------------------------------------------------------------
// Determinism of the checker itself
// ---------------------------------------------------------------------------

/// Two explorations of the same scenario must enumerate the same
/// schedules in the same order — the trace hash covers every decision
/// of every schedule, so any nondeterminism in the scheduler shows up.
#[test]
fn exploration_is_reproducible_across_runs() {
    let run = || explore(&Config::with_bound(2), |env| pool_batch_scenario(env, false));
    let a = run();
    let b = run();
    assert_eq!(a.schedules, b.schedules);
    assert_eq!(a.trace_hash, b.trace_hash);
    assert!(a.failure.is_none() && b.failure.is_none());

    // Same property on a failing scenario: the same bug is found on the
    // same schedule, with the same decision sequence.
    let fail = || explore(&Config::with_bound(2), |env| gen_fence_scenario(env, 1));
    let a = fail();
    let b = fail();
    let (fa, fb) = (a.assert_finding(), b.assert_finding());
    assert_eq!(fa.schedule_index, fb.schedule_index);
    assert_eq!(fa.schedule, fb.schedule);
    assert_eq!(a.trace_hash, b.trace_hash);
}
