//! End-to-end progressive-codec transfer (EXPERIMENTS.md §E2E).
//!
//! The paper's headline workflow on real machinery, no simulation in the
//! data path:
//!
//!   1. generate a synthetic cosmology-like f32 volume (the Nyx
//!      substitute);
//!   2. encode it with `janus::codec` against a requested ε ladder —
//!      multilevel lifting + bitplane segments, every rung's ε
//!      *measured* against the original;
//!   3. transfer the rungs through the `janus::api` facade over a
//!      deterministic 5%-loss 4-stream testkit wire (real wire format,
//!      real Reed–Solomon groups, real retransmission passes);
//!   4. progressively decode on the receive side, checking the decoder's
//!      reported achieved ε against the contract — and against the
//!      ground truth.
//!
//! Run: `cargo run --release --example codec_transfer [seed]`

use janus::api::{run_pair, CodecConfig, Contract, Dataset, EventLog, TransferEvent, TransferSpec};
use janus::model::NetParams;
use janus::refactor::{generate, GrfConfig};
use janus::testkit::{loss_transport_pair, LossTrace};
use std::time::Duration;

const D: usize = 64;
const STREAMS: usize = 4;
const LOSS: f64 = 0.05;

fn main() -> janus::util::err::Result<()> {
    let seed = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(2026u64);

    // ---------- 1. Source volume ----------
    let vol = generate(D, &GrfConfig::default(), seed);
    println!("[1] generated {D}³ synthetic cosmology field (seed {seed})");

    // ---------- 2. Progressive encode against an ε ladder ----------
    let cfg = CodecConfig { levels: 4, ladder: vec![4e-3, 5e-4, 6e-5], max_planes: 24 };
    let dataset = Dataset::from_volume(&vol, &cfg)?;
    let raw = (D * D * D * 4) as u64;
    println!(
        "[2] encoded {} rungs: {} B vs {} B raw ({:.1}%), measured ε {:?}",
        dataset.levels.len(),
        dataset.total_bytes(),
        raw,
        100.0 * dataset.total_bytes() as f64 / raw as f64,
        dataset.eps.iter().map(|e| format!("{e:.2e}")).collect::<Vec<_>>()
    );
    for (rec, req) in dataset.eps.iter().zip(&cfg.ladder) {
        assert!(rec <= req, "encoder must meet every requested rung: {rec} > {req}");
    }

    // ---------- 3. Facade transfer over a 5%-loss wire ----------
    let contracted = *dataset.eps.last().expect("non-empty ladder");
    let rate = 100_000.0;
    let spec = TransferSpec::builder()
        .contract(Contract::Fidelity(contracted))
        .streams(STREAMS)
        .net(NetParams { t: 0.0005, r: rate, lambda: 0.0, n: 32, s: 4096 })
        .initial_lambda(LOSS * rate * STREAMS as f64)
        .lambda_window(0.25)
        .max_duration(Duration::from_secs(300))
        .build()?;
    let (st, rt) =
        loss_transport_pair(STREAMS, |w| LossTrace::seeded(LOSS, seed ^ (w as u64 + 0x7E)));
    let mut receiver_log = EventLog::new();
    let report = run_pair(&spec, st, rt, &dataset, None, Some(&mut receiver_log))?;
    println!(
        "[3] facade transfer: {} streams at {:.0}% loss, {} fragments, {} RS-recovered \
         groups, {} retransmission pass(es)",
        STREAMS,
        LOSS * 100.0,
        report.sent.fragments_sent,
        report.received.groups_recovered,
        report.sent.passes,
    );
    // Fidelity contract ⇒ every rung byte-exact.
    for (li, (got, want)) in report.received.levels.iter().zip(&dataset.levels).enumerate() {
        assert_eq!(got.as_ref().expect("delivered"), want, "rung {li} must survive the wire");
    }

    // ---------- 4. Progressive decode + ε certificate ----------
    let decoded: Vec<(u8, f64)> = receiver_log
        .events
        .iter()
        .filter_map(|e| match e {
            TransferEvent::LevelDecoded { level, achieved_eps } => Some((*level, *achieved_eps)),
            _ => None,
        })
        .collect();
    for (level, eps) in &decoded {
        println!("    LevelDecoded: rung {} → ε ≤ {eps:.3e}", level + 1);
    }
    assert_eq!(decoded.len(), dataset.levels.len(), "every rung decodes");
    assert!(
        decoded.windows(2).all(|w| w[0].1 > w[1].1),
        "achieved ε must tighten rung by rung"
    );
    let out = report
        .received
        .decode_volume()
        .expect("codec stream")
        .expect("full prefix decodes");
    let true_err = vol.linf_rel_error(&out.volume);
    println!(
        "[4] reconstruction: reported ε ≤ {:.3e} (contract {:.3e}), ground-truth ε = {:.3e} → {}",
        out.achieved_eps,
        contracted,
        true_err,
        if true_err <= out.achieved_eps + 1e-12 { "WITHIN BOUND ✓" } else { "VIOLATED ✗" }
    );
    assert!(out.achieved_eps <= contracted + 1e-15, "contract met by the reported bound");
    assert!(true_err <= out.achieved_eps + 1e-12, "reported bound is honest");
    println!(
        "\nheadline: {:.1}% of the raw bytes delivered ε ≤ {:.1e} over a 5%-loss wire, \
         end-to-end certified",
        100.0 * dataset.total_bytes() as f64 / raw as f64,
        out.achieved_eps
    );
    Ok(())
}
