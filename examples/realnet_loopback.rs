//! Real-network (loopback) experiment — the Fig. 6 / Table 2 path,
//! driven through the `janus::api` facade.
//!
//! Runs the actual coordinator engines (threads, real UDP sockets on
//! localhost, Reed–Solomon codec, wire format) with injected fragment
//! loss as the controlled-WAN substitute:
//!
//!   * `Contract::Fidelity` (Alg. 1, guaranteed error bound) with
//!     adaptive redundancy;
//!   * `Contract::Deadline` (Alg. 2) at 90% of Alg. 1's duration;
//!   * repeated over several loss fractions like the paper's five runs.
//!
//! Run: `cargo run --release --example realnet_loopback`

use janus::api::{run_pair, ChannelTransport, Contract, Dataset, TransferSpec};
use janus::model::NetParams;
use janus::refactor::{decompose, generate, levels_to_bytes, reconstruct, GrfConfig};
use janus::transport::{udp_pair, LossyChannel};
use std::time::Duration;

fn main() -> janus::util::err::Result<()> {
    let dim = 64;
    let vol = generate(dim, &GrfConfig::default(), 7);
    let levels = decompose(&vol, 4);
    let bytes = levels_to_bytes(&levels);
    let refs: Vec<&[f32]> = levels.iter().map(|l| l.as_slice()).collect();
    let mut eps: Vec<f64> = (1..=4)
        .map(|u| vol.linf_rel_error(&reconstruct(&refs, u, 4, dim)).max(1e-12))
        .collect();
    for i in 1..4 {
        if eps[i] >= eps[i - 1] {
            eps[i] = eps[i - 1] * 0.999;
        }
    }
    let dataset = Dataset::new(bytes, eps.clone())?;
    let total = dataset.total_bytes();
    println!(
        "payload: {dim}³ field → 4 levels, {total} bytes total, ε {:?}",
        eps.iter().map(|e| format!("{e:.1e}")).collect::<Vec<_>>()
    );

    // Pacing low enough that loopback never overruns socket buffers.
    let rate = 30_000.0;
    let net = NetParams { t: 0.0005, r: rate, n: 32, s: 4096, lambda: 0.0 };
    let spec_for = |contract: Contract, initial_lambda: f64| {
        TransferSpec::builder()
            .contract(contract)
            .net(net)
            .initial_lambda(initial_lambda)
            .lambda_window(0.25)
            .idle_timeout(Duration::from_secs(10))
            .max_duration(Duration::from_secs(120))
            .build()
            .expect("loopback spec")
    };

    println!(
        "\n{:<8} {:>10} {:>12} {:>10} {:>12} {:>8}",
        "loss", "alg1 s", "alg1 passes", "alg2 s", "alg2 levels", "ε met"
    );
    for (run, loss_fraction) in [0.001, 0.01, 0.02, 0.03, 0.05].iter().enumerate() {
        // ---- Alg. 1: guaranteed error bound over lossy UDP ----
        let (tx, rx) = udp_pair()?;
        let sender_t = ChannelTransport::new(LossyChannel::new(tx, *loss_fraction, 1000 + run as u64));
        let receiver_t = ChannelTransport::new(rx);
        let spec = spec_for(Contract::Fidelity(eps[3]), loss_fraction * rate);
        let r1 = run_pair(&spec, sender_t, receiver_t, &dataset, None, None)?;
        assert_eq!(r1.received.levels_recovered, 4, "Alg.1 must deliver everything");
        // Verify the delivered bytes decode to the exact field.
        let got: Vec<Vec<f32>> = r1
            .received
            .levels
            .iter()
            .map(|l| janus::refactor::bytes_to_level(l.as_ref().unwrap()))
            .collect();
        let grefs: Vec<&[f32]> = got.iter().map(|l| l.as_slice()).collect();
        let recon = reconstruct(&grefs, 4, 4, dim);
        let err = vol.linf_rel_error(&recon);
        assert!(err <= eps[3] * 1.001, "ε violated after real transfer: {err}");

        // ---- Alg. 2: deadline at 90% of Alg. 1's wall time ----
        let tau = 0.9 * r1.received.duration;
        let (tx2, rx2) = udp_pair()?;
        let sender_t2 =
            ChannelTransport::new(LossyChannel::new(tx2, *loss_fraction, 2000 + run as u64));
        let receiver_t2 = ChannelTransport::new(rx2);
        let spec2 = spec_for(Contract::Deadline(tau), loss_fraction * rate);
        let r2 = run_pair(&spec2, sender_t2, receiver_t2, &dataset, None, None)?;
        println!(
            "{:<8} {:>10.3} {:>12} {:>10.3} {:>12} {:>8}",
            format!("{:.1}%", loss_fraction * 100.0),
            r1.received.duration,
            r1.sent.passes,
            r2.received.duration,
            format!("{}/{}", r2.received.levels_recovered, r2.received.levels.len()),
            if err <= eps[3] * 1.001 { "✓" } else { "✗" },
        );
    }
    println!("\nAlg.1 delivered byte-exact data at every loss rate (ε_4 contract).");
    println!("Alg.2 traded accuracy for a 10% shorter, deterministic deadline (Table 2).");
    Ok(())
}
