//! Quickstart: the Janus public API in five minutes.
//!
//! 1. Describe the network and the refactored dataset.
//! 2. Solve the paper's two optimization models (Eq. 8, Eq. 12).
//! 3. Run simulated transfers under static and time-varying loss.
//! 4. Run a *real* multi-stream transfer through the `janus::api`
//!    facade: spec → endpoint pair → byte-exact delivery.
//!
//! Run: `cargo run --release --example quickstart`

use janus::api::{mem_transport_pair, run_pair, Contract, Dataset, TransferSpec};
use janus::model::{
    optimize_deadline_paper, optimize_parity, LevelSchedule, NetParams,
};
use janus::sim::{
    run_guaranteed_error, run_guaranteed_time, DeadlinePolicy, HmmLoss, ParityPolicy, StaticLoss,
};

fn main() {
    // --- 1. Setup: the paper's measured testbed + Nyx level schedule,
    // scaled 1/100 so this demo runs in seconds. -------------------------
    let lambda = 383.0; // medium loss: 2% of the link rate (§5.2.2)
    let params = NetParams::paper_default(lambda);
    let sched = LevelSchedule::paper_nyx_scaled(100);
    println!(
        "network: t={}s r={} pkt/s n={} s={}B   λ={lambda}/s",
        params.t, params.r, params.n, params.s
    );
    println!(
        "levels: {:?} bytes, ε = {:?}\n",
        sched.sizes, sched.eps
    );

    // --- 2a. Guaranteed error bound (Alg. 1): choose m via Eq. 8. -------
    let bytes = sched.total_bytes(4);
    let opt = optimize_parity(&params, bytes);
    println!(
        "Eq.8  → m = {:>2} parity fragments per 32-fragment FTG \
         (E[T] = {:.2}s, p_unrec = {:.2e})",
        opt.m, opt.expected_time, opt.p_unrecoverable
    );

    // --- 2b. Guaranteed time (Alg. 2): choose [m_1..m_4] via Eq. 12. ----
    let tau = opt.expected_time; // spend exactly the Alg. 1 budget
    let plan = optimize_deadline_paper(&params, &sched, tau).expect("feasible");
    println!(
        "Eq.12 → send {} levels with m = {:?} (E[ε] = {:.2e}, time = {:.2}s)\n",
        plan.levels, plan.m, plan.expected_error, plan.time
    );

    // --- 3a. Simulate Alg. 1 under static loss. -------------------------
    let ttl = 1.0 / params.r;
    let mut loss = StaticLoss::with_ttl(lambda, 42, ttl);
    let res = run_guaranteed_error(
        &mut loss,
        &params,
        &sched,
        4,
        &ParityPolicy::Adaptive { t_w: 3.0, initial_lambda: lambda },
    );
    println!(
        "Alg.1 (static λ): delivered all 4 levels in {:.2}s \
         ({} retransmission rounds, {} fragments lost)",
        res.total_time, res.rounds, res.fragments_lost
    );

    // --- 3b. Simulate Alg. 2 under the paper's time-varying HMM loss. ---
    let mut hmm = HmmLoss::paper_default_with_ttl(42, ttl);
    let res = run_guaranteed_time(
        &mut hmm,
        &params,
        &sched,
        tau,
        &DeadlinePolicy::Adaptive { t_w: 3.0, initial_lambda: lambda },
    )
    .expect("feasible");
    println!(
        "Alg.2 (HMM λ):   {} of {} levels within τ = {:.2}s → ε ≤ {:.1e} \
         ({} plan adaptations)",
        res.levels_recovered,
        res.levels_sent,
        tau,
        res.achieved_eps,
        res.plan_changes.len().saturating_sub(1),
    );

    // --- 4. Real transfer through the api facade (in-memory wire). ------
    let mut rng = janus::util::Pcg64::seeded(7);
    let levels: Vec<Vec<u8>> = [40_000usize, 160_000]
        .iter()
        .map(|&sz| {
            let mut v = vec![0u8; sz];
            rng.fill_bytes(&mut v);
            v
        })
        .collect();
    let dataset = Dataset::new(levels, vec![0.004, 0.0000001]).expect("valid dataset");
    let spec = TransferSpec::builder()
        .contract(Contract::Fidelity(1e-7))
        .streams(4)
        .net(NetParams { t: 0.0005, r: 200_000.0, lambda: 0.0, n: 32, s: 1024 })
        .lambda_window(0.25)
        .build()
        .expect("valid spec");
    let (sender_t, receiver_t) = mem_transport_pair(spec.streams());
    let report = run_pair(&spec, sender_t, receiver_t, &dataset, None, None).expect("transfer");
    assert!(report
        .received
        .levels
        .iter()
        .zip(&dataset.levels)
        .all(|(got, want)| got.as_deref() == Some(want.as_slice())));
    println!(
        "\napi facade:      {} streams delivered {} bytes byte-exact in {:.2}s \
         ({} fragments on the wire)",
        spec.streams(),
        dataset.total_bytes(),
        report.received.duration,
        report.sent.fragments_sent,
    );
}
