//! Multi-stream TransferPool walkthrough — shard a refactored dataset
//! across 4 concurrent paced streams over a deterministic lossy WAN
//! substitute, watch the shared λ̂ estimator converge, and verify the
//! delivery is byte-exact.
//!
//! Run: `cargo run --release --example pool_transfer [-- --streams 8]`

use janus::config::Args;
use janus::coordinator::{PoolConfig, ReceiverConfig, TransferPool};
use janus::model::NetParams;
use janus::refactor::{decompose, generate, levels_to_bytes, GrfConfig};
use janus::testkit::{pool_fixture, LossTrace};
use std::time::{Duration, Instant};

fn main() {
    let args = Args::from_env();
    let streams = args.get_usize_in("streams", 4, 1, 255);
    let loss = args.get_f64("loss", 0.02);
    let seed = args.get_u64("seed", 2026);

    // 1. A refactored scientific dataset (the Nyx substitute).
    let dim = 64;
    let vol = generate(dim, &GrfConfig::default(), seed);
    let levels = decompose(&vol, 4);
    let bytes = levels_to_bytes(&levels);
    let eps = vec![0.004, 0.0005, 0.00006, 0.0000001];
    let total: usize = bytes.iter().map(|b| b.len()).sum();
    println!(
        "dataset: {dim}³ field → {} levels, {:.1} MB total",
        bytes.len(),
        total as f64 / 1e6
    );

    // 2. A pool over N streams, each paced independently.
    let rate = 100_000.0;
    let pool = TransferPool::new(PoolConfig {
        net: NetParams { t: 0.0005, r: rate, lambda: 0.0, n: 32, s: 4096 },
        streams,
        error_bound: 1e-7,
        initial_lambda: loss * rate * streams as f64,
        max_duration: Duration::from_secs(300),
    })
    .expect("valid pool config");

    // 3. Deterministic loss on every data stream; lossless control.
    let (mut sc, sd, mut rc, rd) =
        pool_fixture(streams, |w| LossTrace::seeded(loss, seed ^ (w as u64 + 1)));
    let rcfg = ReceiverConfig {
        t_w: 0.25,
        idle_timeout: Duration::from_secs(10),
        max_duration: Duration::from_secs(300),
    };
    let t0 = Instant::now();
    let (s_rep, r_rep) = pool
        .run_session(&mut sc, sd, &mut rc, rd, &rcfg, &bytes, &eps)
        .expect("pool transfer");
    let wall = t0.elapsed().as_secs_f64();

    // 4. Byte-exactness + the per-pass adaptation story.
    for (li, (got, want)) in r_rep.levels.iter().zip(&bytes).enumerate() {
        assert_eq!(got.as_ref().unwrap(), want, "level {li} must be exact");
    }
    println!(
        "\n{:<6} {:>4} {:>10} {:>10} {:>12} {:>10}",
        "pass", "m", "ftgs", "fragments", "λ̂ (loss/s)", "lost ftgs"
    );
    for p in &s_rep.trace {
        println!(
            "{:<6} {:>4} {:>10} {:>10} {:>12.0} {:>10}",
            p.pass, p.m, p.ftgs, p.fragments, p.lambda_hat, p.lost_ftgs
        );
    }
    println!(
        "\n{} streams delivered {:.1} MB byte-exact in {wall:.2}s \
         ({:.1} MB/s aggregate; {} RS-recovered groups, {} retransmission passes)",
        streams,
        total as f64 / 1e6,
        total as f64 / 1e6 / wall,
        r_rep.groups_recovered,
        s_rep.passes
    );
    let expect_lambda = loss * rate * streams as f64;
    println!(
        "shared λ̂ after pass 0: {:.0} losses/s (injected regime ≈ {expect_lambda:.0})",
        s_rep.lambda_history[0]
    );
}
