//! Multi-stream transfer walkthrough over the `janus::api` facade —
//! shard a refactored dataset across 4 concurrent paced streams over a
//! deterministic lossy WAN substitute, watch the shared λ̂ estimator
//! converge through typed observer events, and verify the delivery is
//! byte-exact.
//!
//! Run: `cargo run --release --example pool_transfer [-- --streams 8]`

use janus::api::{run_pair, Contract, Dataset, EventLog, TransferEvent, TransferSpec};
use janus::config::Args;
use janus::model::NetParams;
use janus::refactor::{decompose, generate, levels_to_bytes, GrfConfig};
use janus::testkit::{loss_transport_pair, LossTrace};
use std::time::{Duration, Instant};

fn main() {
    let args = Args::from_env();
    let streams = args.get_usize_in("streams", 4, 1, 255);
    let loss = args.get_f64("loss", 0.02);
    let seed = args.get_u64("seed", 2026);

    // 1. A refactored scientific dataset (the Nyx substitute).
    let dim = 64;
    let vol = generate(dim, &GrfConfig::default(), seed);
    let levels = decompose(&vol, 4);
    let bytes = levels_to_bytes(&levels);
    let eps = vec![0.004, 0.0005, 0.00006, 0.0000001];
    let dataset = Dataset::new(bytes, eps).expect("well-formed dataset");
    let total = dataset.total_bytes();
    println!(
        "dataset: {dim}³ field → {} levels, {:.1} MB total",
        dataset.levels.len(),
        total as f64 / 1e6
    );

    // 2. One spec describes the whole transfer: contract, streams, pacing.
    let rate = 100_000.0;
    let spec = TransferSpec::builder()
        .contract(Contract::Fidelity(1e-7))
        .streams(streams)
        .net(NetParams { t: 0.0005, r: rate, lambda: 0.0, n: 32, s: 4096 })
        .initial_lambda(loss * rate * streams as f64)
        .lambda_window(0.25)
        .idle_timeout(Duration::from_secs(10))
        .max_duration(Duration::from_secs(300))
        .build()
        .expect("valid transfer spec");

    // 3. Deterministic loss on every data stream; lossless control. The
    //    observer sees the protocol live: passes, parity, λ̂, streams.
    let (sender_t, receiver_t) =
        loss_transport_pair(streams, |w| LossTrace::seeded(loss, seed ^ (w as u64 + 1)));
    let mut events = EventLog::new();
    let t0 = Instant::now();
    let report = run_pair(&spec, sender_t, receiver_t, &dataset, Some(&mut events), None)
        .expect("pool transfer");
    let wall = t0.elapsed().as_secs_f64();

    // 4. Byte-exactness + the per-pass adaptation story.
    for (li, (got, want)) in report.received.levels.iter().zip(&dataset.levels).enumerate() {
        assert_eq!(got.as_ref().unwrap(), want, "level {li} must be exact");
    }
    if let Some(trace) = report.sent.trace() {
        println!(
            "\n{:<6} {:>4} {:>10} {:>10} {:>12} {:>10}",
            "pass", "m", "ftgs", "fragments", "λ̂ (loss/s)", "lost ftgs"
        );
        for p in trace {
            println!(
                "{:<6} {:>4} {:>10} {:>10} {:>12.0} {:>10}",
                p.pass, p.m, p.ftgs, p.fragments, p.lambda_hat, p.lost_ftgs
            );
        }
    }
    let stream_events = events
        .filtered(|e| matches!(e, TransferEvent::StreamFinished { .. }))
        .len();
    let lambda_events = events
        .filtered(|e| matches!(e, TransferEvent::LambdaUpdated { .. }))
        .len();
    println!(
        "\nobserver saw {} events ({} StreamFinished, {} LambdaUpdated)",
        events.events.len(),
        stream_events,
        lambda_events
    );
    println!(
        "{} streams delivered {:.1} MB byte-exact in {wall:.2}s \
         ({:.1} MB/s aggregate; {} RS-recovered groups, {} retransmission passes)",
        streams,
        total as f64 / 1e6,
        total as f64 / 1e6 / wall,
        report.received.groups_recovered,
        report.sent.passes
    );
    let expect_lambda = loss * rate * streams as f64;
    if let Some(first) = report.sent.lambda_history.first() {
        println!(
            "shared λ̂ after pass 0: {first:.0} losses/s (injected regime ≈ {expect_lambda:.0})"
        );
    }
}
