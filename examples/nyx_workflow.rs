//! End-to-end cross-facility workflow — the headline validation run
//! (EXPERIMENTS.md §E2E).
//!
//! Exercises every layer of the stack on a real small workload:
//!
//!   1. generate a synthetic cosmology-like 3-D field (the Nyx substitute);
//!   2. refactor it into 4 hierarchical levels through the **PJRT-loaded
//!      L2/L1 artifact** (JAX + Pallas, AOT-compiled to HLO text);
//!   3. transfer the levels over the simulated WAN under the paper's
//!      time-varying (HMM) packet loss with the adaptive protocols
//!      (Alg. 1 guaranteed-ε, then Alg. 2 guaranteed-time at 90% of
//!      Alg. 1's time — the Table 2 setup), then once more for real —
//!      the actual engines via the `janus::api` facade over a 5%-loss
//!      deterministic wire;
//!   4. reconstruct on the receive side through the PJRT reconstruction
//!      artifact and measure the relative L∞ error actually achieved.
//!
//! Requires `make artifacts` (D = 64 default). Run:
//!   `cargo run --release --example nyx_workflow`

use janus::api::{run_pair, Contract, Dataset, TransferSpec};
use janus::model::{LevelSchedule, NetParams};
use janus::refactor::{generate, GrfConfig, Volume};
use janus::runtime::{default_artifact_dir, F32Input, Runtime};
use janus::sim::{
    run_guaranteed_error, run_guaranteed_time, DeadlinePolicy, HmmLoss, ParityPolicy,
};
use janus::testkit::{loss_transport_pair, LossTrace};

const D: usize = 64;
const L: usize = 4;

fn main() -> janus::util::err::Result<()> {
    let seed = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(2026u64);

    // ---------- 1. Source data (Nyx substitute) ----------
    let vol = generate(D, &GrfConfig::default(), seed);
    println!("[1] generated {D}³ synthetic cosmology field (seed {seed})");

    // ---------- 2. Refactor via the PJRT artifact (L1+L2+runtime) ------
    let mut rt = Runtime::open(default_artifact_dir())?;
    let t0 = std::time::Instant::now();
    let levels = rt.run_f32(
        &format!("refactor_d{D}_l{L}"),
        &[F32Input::shaped(&vol.data, &[D, D, D])],
    )?;
    let refactor_secs = t0.elapsed().as_secs_f64();
    let sizes: Vec<u64> = levels.iter().map(|l| (l.len() * 4) as u64).collect();

    // Measured ε per level through the PJRT reconstruction + error
    // artifacts (the numbers a real deployment would publish).
    let mut eps = Vec::new();
    for used in 1..=L {
        let inputs: Vec<F32Input> = levels[..used].iter().map(|l| F32Input::vec(l)).collect();
        let approx = rt.run_f32(&format!("reconstruct_d{D}_l{L}_u{used}"), &inputs)?;
        let err = rt.run_f32(
            &format!("linf_error_d{D}"),
            &[
                F32Input::shaped(&vol.data, &[D, D, D]),
                F32Input::shaped(&approx[0], &[D, D, D]),
            ],
        )?[0][0] as f64;
        eps.push(err.max(1e-12));
    }
    for i in 1..eps.len() {
        if eps[i] >= eps[i - 1] {
            eps[i] = eps[i - 1] * 0.999; // guard strict monotonicity
        }
    }
    println!(
        "[2] refactored via PJRT artifact in {refactor_secs:.2}s: sizes {sizes:?} B, ε {:?}",
        eps.iter().map(|e| format!("{e:.2e}")).collect::<Vec<_>>()
    );

    // ---------- 3a. Transfer with Alg. 1 under HMM loss ----------
    let sched = LevelSchedule::new(sizes.clone(), eps.clone());
    let params = NetParams::paper_default(383.0);
    let ttl = 1.0 / params.r;
    let mut loss = HmmLoss::paper_default_with_ttl(seed, ttl);
    let res1 = run_guaranteed_error(
        &mut loss,
        &params,
        &sched,
        L,
        &ParityPolicy::Adaptive { t_w: 3.0, initial_lambda: 383.0 },
    );
    println!(
        "[3a] Alg.1 (guaranteed ε = {:.1e}): {:.3}s sim, {} rounds, {} lost, m path {:?}",
        eps[L - 1],
        res1.total_time,
        res1.rounds,
        res1.fragments_lost,
        res1.m_changes
    );

    // ---------- 3b. Alg. 2 at τ = 90% of Alg. 1's time (Table 2) -------
    let tau = 0.9 * res1.total_time;
    let mut loss2 = HmmLoss::paper_default_with_ttl(seed ^ 0xA1, ttl);
    let res2 = run_guaranteed_time(
        &mut loss2,
        &params,
        &sched,
        tau,
        &DeadlinePolicy::Adaptive { t_w: 3.0, initial_lambda: 383.0 },
    )
    .ok_or_else(|| janus::anyhow!("τ infeasible"))?;
    println!(
        "[3b] Alg.2 (τ = {tau:.3}s): finished {:.3}s, recovered {}/{} levels",
        res2.total_time, res2.levels_recovered, res2.levels_sent
    );

    // ---------- 3c. The real engines via the api facade ----------
    // Same refactored bytes, actual wire format + RS codec + pass
    // protocol, over a deterministic 5%-loss 4-stream channel set.
    let dataset = Dataset::new(janus::refactor::levels_to_bytes(&levels), eps.clone())?;
    let streams = 4;
    let rate = 100_000.0;
    let spec = TransferSpec::builder()
        .contract(Contract::Fidelity(eps[L - 1]))
        .streams(streams)
        .net(NetParams { t: 0.0005, r: rate, lambda: 0.0, n: 32, s: 4096 })
        .initial_lambda(0.05 * rate * streams as f64)
        .lambda_window(0.25)
        .max_duration(std::time::Duration::from_secs(300))
        .build()?;
    let (sender_t, receiver_t) =
        loss_transport_pair(streams, |w| LossTrace::seeded(0.05, seed ^ (w as u64 + 0x3C)));
    let wire = run_pair(&spec, sender_t, receiver_t, &dataset, None, None)?;
    for (li, (got, want)) in wire.received.levels.iter().zip(&dataset.levels).enumerate() {
        assert_eq!(got.as_ref().unwrap(), want, "level {li} must survive the wire");
    }
    println!(
        "[3c] api facade over 5%-loss wire: {} streams, {} fragments, \
         {} RS-recovered groups, {} retransmission pass(es), byte-exact",
        streams,
        wire.sent.fragments_sent,
        wire.received.groups_recovered,
        wire.sent.passes
    );

    // ---------- 4. Receive-side reconstruction via PJRT ----------
    let usable = res2.levels_recovered.max(1);
    let inputs: Vec<F32Input> = levels[..usable].iter().map(|l| F32Input::vec(l)).collect();
    let approx = rt.run_f32(&format!("reconstruct_d{D}_l{L}_u{usable}"), &inputs)?;
    let achieved = Volume::new(D, approx[0].clone());
    let measured = vol.linf_rel_error(&achieved);
    println!(
        "[4] receive-side PJRT reconstruction from {usable} levels: measured ε = {measured:.3e} \
         (contract ε_{usable} = {:.3e}) → {}",
        eps[usable - 1],
        if measured <= eps[usable - 1] * 1.0001 { "WITHIN BOUND ✓" } else { "VIOLATED ✗" }
    );
    assert!(
        measured <= eps[usable - 1] * 1.0001,
        "error bound violated: {measured} > {}",
        eps[usable - 1]
    );

    println!(
        "\nheadline: Alg.1 delivered ε ≤ {:.1e} in {:.3}s; Alg.2 delivered ε ≤ {:.1e} in {:.3}s (90% budget)",
        eps[L - 1],
        res1.total_time,
        res2.achieved_eps,
        res2.total_time
    );
    Ok(())
}
