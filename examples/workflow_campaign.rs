//! Cross-facility workflow campaign — the paper's motivating scenario
//! (§1): an experimental facility streams several datasets to remote
//! collaborators with mixed urgency over one WAN uplink.
//!
//! Six jobs over the Janus orchestrator, sharing the paper's measured
//! link under time-varying (HMM) loss:
//!   * three bulk archives (guaranteed ε, low weight);
//!   * two "quick-look" visualizations (guaranteed time, high weight);
//!   * one urgent full-fidelity dataset (guaranteed ε, high weight).
//!
//! Run: `cargo run --release --example workflow_campaign`

use janus::api::Contract;
use janus::model::{LevelSchedule, NetParams};
use janus::sim::HmmLoss;
use janus::workflow::{run_campaign, Job, SchedulerConfig};

fn main() {
    let net = NetParams::paper_default(383.0);
    let cfg = SchedulerConfig { net, t_w: 3.0, initial_lambda: 383.0, streams: 1 };
    let sched_big = LevelSchedule::paper_nyx_scaled(200); // ~134 MB each
    let sched_small = LevelSchedule::paper_nyx_scaled(1000); // ~27 MB each

    let jobs = vec![
        Job { id: 0, sched: sched_big.clone(), contract: Contract::Fidelity(1e-7), weight: 1, arrival: 0.0 },
        Job { id: 1, sched: sched_big.clone(), contract: Contract::Fidelity(1e-7), weight: 1, arrival: 0.0 },
        Job { id: 2, sched: sched_small.clone(), contract: Contract::Deadline(20.0), weight: 4, arrival: 2.0 },
        Job { id: 3, sched: sched_big.clone(), contract: Contract::Fidelity(1e-7), weight: 1, arrival: 5.0 },
        Job { id: 4, sched: sched_small.clone(), contract: Contract::Deadline(15.0), weight: 4, arrival: 30.0 },
        Job { id: 5, sched: sched_big, contract: Contract::Fidelity(1e-7), weight: 3, arrival: 40.0 },
    ];

    let mut loss = HmmLoss::paper_default_with_ttl(2026, 1.0 / net.r);
    let res = run_campaign(&cfg, jobs, &mut loss);

    println!(
        "{:<4} {:>9} {:>9} {:>9} {:>10} {:>9} {:>10} {:>9}",
        "job", "arrive", "finish", "levels", "ε", "contract", "frags", "retxFTG"
    );
    for j in &res.jobs {
        println!(
            "{:<4} {:>9.2} {:>9.2} {:>9} {:>10.1e} {:>9} {:>10} {:>9}",
            j.id,
            j.start,
            j.finish,
            format!("{}/{}", j.levels_recovered, j.levels_sent),
            j.achieved_eps,
            if j.met_contract { "MET ✓" } else { "MISS ✗" },
            j.fragments_sent,
            j.retransmitted_ftgs,
        );
    }
    println!(
        "\nmakespan {:.2}s, link utilization {:.1}%, λ̂ samples {}",
        res.makespan,
        res.link_utilization * 100.0,
        res.lambda_trace.len()
    );
    let met = res.jobs.iter().filter(|j| j.met_contract).count();
    println!("{met}/{} contracts met", res.jobs.len());
    assert!(met >= 5, "campaign should meet (nearly) all contracts");
}
