"""L2 correctness: multilevel refactor / progressive reconstruction."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def rand_volume(d, seed):
    return jnp.array(np.random.RandomState(seed).randn(d, d, d), jnp.float32)


def smooth_volume(d, seed, kmax=2):
    rs = np.random.RandomState(seed)
    g = np.stack(
        np.meshgrid(*[np.linspace(0, 2 * np.pi, d, endpoint=False)] * 3, indexing="ij")
    )
    f = np.ones((d, d, d)) * 3.0
    for _ in range(12):
        k = rs.randint(1, kmax + 1, 3)
        ph = rs.rand(3) * 2 * np.pi
        amp = 1.0 / (k.sum() ** 2)
        f += (
            amp
            * np.cos(k[0] * g[0] + ph[0])
            * np.cos(k[1] * g[1] + ph[1])
            * np.cos(k[2] * g[2] + ph[2])
        )
    return jnp.array(f, jnp.float32)


@pytest.mark.parametrize("d,levels", [(16, 2), (16, 3), (32, 4), (64, 4)])
def test_full_roundtrip_exact(d, levels):
    x = rand_volume(d, 1)
    bufs = model.refactor(x, levels)
    xi = model.reconstruct(list(bufs), levels, levels, d)
    np.testing.assert_allclose(xi, x, rtol=1e-4, atol=1e-4)


def test_matches_reference_decomposition():
    x = rand_volume(32, 2)
    got = model.refactor(x, 4)
    want = ref.decompose_ref(x, 4)
    assert len(got) == len(want) == 4
    for a, b in zip(got, want):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


def test_level_sizes_match_buffers():
    x = rand_volume(32, 3)
    bufs = model.refactor(x, 4)
    sizes = model.level_sizes(32, 4)
    assert [b.size * 4 for b in bufs] == sizes
    # Sizes grow monotonically (paper: S_1 < S_2 < ... < S_L).
    assert all(sizes[i] < sizes[i + 1] for i in range(len(sizes) - 1))


def test_progressive_error_decreases_on_smooth_field():
    d = 32
    x = smooth_volume(d, 4)
    bufs = model.refactor(x, 4)
    errs = [
        float(model.linf_rel_error(x, model.reconstruct(list(bufs), u, 4, d)))
        for u in range(1, 5)
    ]
    for a, b in zip(errs, errs[1:]):
        assert a > b, f"eps must strictly decrease: {errs}"
    assert errs[-1] < 1e-5, f"full reconstruction eps too high: {errs[-1]}"


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_roundtrip_property_16(seed):
    x = rand_volume(16, seed)
    bufs = model.refactor(x, 3)
    xi = model.reconstruct(list(bufs), 3, 3, 16)
    np.testing.assert_allclose(xi, x, rtol=1e-4, atol=1e-4)


def test_linf_error_metric():
    a = jnp.ones((4, 4, 4), jnp.float32) * 2.0
    b = a.at[0, 0, 0].set(2.5)
    # max|a-b| / max|a| = 0.5 / 2.0
    assert abs(float(model.linf_rel_error(a, b)) - 0.25) < 1e-6
    assert float(model.linf_rel_error(a, a)) == 0.0
