"""L1 correctness: Pallas lifting kernels vs the pure-jnp oracle.

The CORE correctness signal of the compile path: hypothesis sweeps shapes
and data, asserting allclose between kernel and ref, plus perfect
reconstruction through forward+inverse.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.lift import lift_forward, lift_inverse
from compile.kernels.ref import (
    lift_forward_ref,
    lift_inverse_ref,
    lift3d_forward_ref,
    lift3d_inverse_ref,
)

RTOL, ATOL = 1e-5, 1e-5


def rand(shape, seed):
    return jnp.array(np.random.RandomState(seed).randn(*shape), jnp.float32)


@pytest.mark.parametrize("rows,w", [(8, 8), (16, 64), (64, 256), (8, 4096), (1, 2)])
def test_forward_matches_ref(rows, w):
    x = rand((rows, w), 0)
    c, d = lift_forward(x)
    cr, dr = lift_forward_ref(x)
    np.testing.assert_allclose(c, cr, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(d, dr, rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("rows,w", [(8, 8), (16, 64), (64, 256), (1, 2)])
def test_inverse_matches_ref(rows, w):
    c = rand((rows, w // 2), 1)
    d = rand((rows, w // 2), 2)
    xi = lift_inverse(c, d)
    xr = lift_inverse_ref(c, d)
    np.testing.assert_allclose(xi, xr, rtol=RTOL, atol=ATOL)


@settings(max_examples=40, deadline=None)
@given(
    rows_pow=st.integers(0, 6),
    w_pow=st.integers(1, 9),
    seed=st.integers(0, 2**31 - 1),
)
def test_roundtrip_property(rows_pow, w_pow, seed):
    """forward âˆ˜ inverse == identity for every power-of-two shape."""
    rows, w = 1 << rows_pow, 1 << w_pow
    x = rand((rows, w), seed)
    c, d = lift_forward(x)
    xi = lift_inverse(c, d)
    np.testing.assert_allclose(xi, x, rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_kernel_vs_ref_property(seed):
    x = rand((16, 128), seed)
    c, d = lift_forward(x)
    cr, dr = lift_forward_ref(x)
    np.testing.assert_allclose(c, cr, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(d, dr, rtol=RTOL, atol=ATOL)


def test_constant_field_has_zero_detail():
    """A constant signal is fully captured by the coarse samples."""
    x = jnp.full((4, 32), 7.5, jnp.float32)
    c, d = lift_forward(x)
    np.testing.assert_allclose(d, jnp.zeros_like(d), atol=1e-6)
    np.testing.assert_allclose(c, jnp.full_like(c, 7.5), atol=1e-6)


def test_linear_ramp_has_zero_interior_detail():
    """The neighbour-average predictor is exact on linear signals."""
    x = jnp.tile(jnp.arange(64, dtype=jnp.float32), (3, 1))
    _, d = lift_forward(x)
    # Interior details vanish; the boundary column uses one-sided predict.
    np.testing.assert_allclose(d[:, :-1], jnp.zeros_like(d[:, :-1]), atol=1e-5)


def test_3d_separable_roundtrip():
    x = rand((16, 16, 16), 5)
    y = lift3d_forward_ref(x)
    xi = lift3d_inverse_ref(y)
    np.testing.assert_allclose(xi, x, rtol=1e-4, atol=1e-4)


def test_blocking_invariance():
    """Different BLOCK_ROWS tilings produce identical results."""
    x = rand((32, 64), 9)
    c1, d1 = lift_forward(x, block_rows=4)
    c2, d2 = lift_forward(x, block_rows=32)
    np.testing.assert_allclose(c1, c2, atol=0)
    np.testing.assert_allclose(d1, d2, atol=0)
