"""AOT export: HLO text artifacts parse back and evaluate correctly."""

import os

import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import aot, model


def test_export_produces_parseable_hlo(tmp_path):
    out = str(tmp_path)
    aot.export(out, dim=16, levels=3, verbose=False)
    names = sorted(os.listdir(out))
    assert "manifest.tsv" in names
    hlo_files = [n for n in names if n.endswith(".hlo.txt")]
    # refactor + 3 reconstruct variants + error metric.
    assert len(hlo_files) == 5
    for fname in hlo_files:
        text = open(os.path.join(out, fname)).read()
        assert "ENTRY" in text, f"{fname} lacks an ENTRY computation"
        # Must not contain Mosaic custom-calls (interpret=True contract).
        assert "tpu_custom_call" not in text, f"{fname} has a TPU custom call"


def test_exported_hlo_text_parses_back(tmp_path):
    """The HLO text must parse back into an HloModule (the same parser
    path the Rust runtime's XLA uses) and preserve the entry signature.
    End-to-end numerical validation of artifact execution happens in the
    Rust integration tests (rust/tests/runtime_artifacts.rs)."""
    out = str(tmp_path)
    aot.export(out, dim=16, levels=3, verbose=False)
    text = open(os.path.join(out, "refactor_d16_l3.hlo.txt")).read()
    mod = xc._xla.hlo_module_from_text(text)
    rt = mod.to_string()
    assert "ENTRY" in rt
    assert "f32[16,16,16]" in rt, "entry parameter shape lost in round-trip"
    # One output buffer per level.
    assert "f32[64]" in rt and "f32[448]" in rt and "f32[3584]" in rt


def test_manifest_lists_all_artifacts(tmp_path):
    out = str(tmp_path)
    aot.export(out, dim=16, levels=2, verbose=False)
    lines = [
        l.strip().split("\t")
        for l in open(os.path.join(out, "manifest.tsv"))
        if not l.startswith("#")
    ]
    names = {l[0] for l in lines}
    assert names == {
        "refactor_d16_l2",
        "reconstruct_d16_l2_u1",
        "reconstruct_d16_l2_u2",
        "linf_error_d16",
    }
    for l in lines:
        assert os.path.exists(os.path.join(out, l[1]))
