"""Pallas quantize kernel vs oracle + bound properties."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.quantize import dequantize_ref, quantize, quantize_ref


def rand(n, seed, scale=4.0):
    return jnp.array(np.random.RandomState(seed).randn(n) * scale, jnp.float32)


def test_kernel_matches_ref():
    x = rand(4096, 0)
    q, s = quantize(x, e_max=3, planes=16)
    qr, sr = quantize_ref(x, e_max=3, planes=16)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(sr))


def test_small_input_single_block():
    x = rand(64, 1)
    q, s = quantize(x, e_max=3, planes=12)
    qr, sr = quantize_ref(x, e_max=3, planes=12)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(sr))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), planes=st.integers(6, 22))
def test_roundtrip_error_within_half_lsb(seed, planes):
    x = rand(1024, seed, scale=2.0)
    e_max = 2  # |x| < 4 = 2^2 whp; clip to be safe
    x = jnp.clip(x, -3.99, 3.99)
    q, s = quantize(x, e_max=e_max, planes=planes)
    back = dequantize_ref(q, s, e_max=e_max, planes=planes)
    lsb = 2.0 ** (e_max - planes)
    # 0.5 lsb from rounding, plus up to 0.5 lsb when the top-of-range
    # clamp (q <= 2^planes - 1) engages near |x| = 2^e_max.
    assert float(jnp.max(jnp.abs(back - x))) <= 1.0 * lsb + 1e-7


def test_zero_maps_to_zero():
    x = jnp.zeros(1024, jnp.float32)
    q, s = quantize(x, e_max=0, planes=10)
    assert int(jnp.sum(q)) == 0
    assert int(jnp.sum(s)) == 0
