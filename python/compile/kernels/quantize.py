"""Layer-1 Pallas kernel: shared-exponent quantization for bitplane
encoding (paper section 2.2 -- pMGARD stores multilevel coefficients as
bitplanes; this kernel produces the sign/magnitude integer field the
bitplane transpose consumes; the transpose itself is byte-shuffling and
lives on the Rust side, rust/src/refactor/bitplane.rs).

interpret=True like all Janus kernels (CPU PJRT contract).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 1024


def _quant_kernel(x_ref, scale_ref, q_ref, s_ref):
    x = x_ref[...]
    scale = scale_ref[0]
    mag = jnp.abs(x) * scale
    q = jnp.clip(jnp.round(mag), 0, 2**30).astype(jnp.int32)
    q_ref[...] = q
    s_ref[...] = (x < 0).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("planes",))
def quantize(x, e_max, planes=16):
    """Quantize a flat f32 array against a shared exponent.

    Returns (q, signs): int32 magnitudes in [0, 2^planes) relative to
    2^(e_max - planes), and 0/1 sign flags.
    """
    n = x.shape[0]
    assert n % BLOCK == 0 or n < BLOCK, f"n={n} must divide {BLOCK}"
    block = min(BLOCK, n)
    grid = n // block
    scale = jnp.asarray([2.0 ** (planes - e_max)], jnp.float32)
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
        ],
        interpret=True,
    )(x, scale)
    # Clamp to the plane budget (rounding can hit 2^planes exactly).
    return jnp.minimum(q, 2**planes - 1), s


def quantize_ref(x, e_max, planes=16):
    """Pure-jnp oracle."""
    scale = 2.0 ** (planes - e_max)
    q = jnp.clip(jnp.round(jnp.abs(x) * scale), 0, 2**planes - 1).astype(jnp.int32)
    return q, (x < 0).astype(jnp.int32)


def dequantize_ref(q, s, e_max, planes=16):
    inv = 2.0 ** (e_max - planes)
    mag = q.astype(jnp.float32) * inv
    return jnp.where(s == 1, -mag, mag)
