"""Pure-jnp oracle for the lifting kernels.

The multilevel refactoring (our pMGARD substitute, DESIGN.md section 3) is
built from a 1-D interpolation-wavelet lifting step applied separably along
each axis. This module is the correctness reference the Pallas kernels are
pytest-verified against, and is itself unit-tested for perfect
reconstruction.

Forward step along the last axis (W even), CDF(2,2)-style lifting:
    even = x[..., 0::2]
    odd  = x[..., 1::2]
    detail = odd - (even + roll_left(even)) / 2     (predict)
    coarse = even + (roll_right(detail) + detail)/4 (update: local average)

The update step turns the coarse samples into local averages, which is
what gives the multilevel hierarchy its decreasing-error property on
smooth fields (the role of pMGARD's L2 projection). The inverse undoes
update then predict and re-interleaves.
"""

import jax.numpy as jnp


def _predict(even):
    """Neighbour-average predictor for the odd samples."""
    right = jnp.concatenate([even[..., 1:], even[..., -1:]], axis=-1)
    return 0.5 * (even + right)


def _update(detail):
    """Update term making coarse samples local averages (CDF(2,2))."""
    left = jnp.concatenate([detail[..., :1], detail[..., :-1]], axis=-1)
    return 0.25 * (left + detail)


def lift_forward_ref(x):
    """Forward lifting along the last axis. Returns (coarse, detail)."""
    assert x.shape[-1] % 2 == 0, "last axis must be even"
    even = x[..., 0::2]
    odd = x[..., 1::2]
    detail = odd - _predict(even)
    return even + _update(detail), detail


def lift_inverse_ref(coarse, detail):
    """Inverse of :func:`lift_forward_ref`."""
    even = coarse - _update(detail)
    odd = detail + _predict(even)
    stacked = jnp.stack([even, odd], axis=-1)
    return stacked.reshape(*coarse.shape[:-1], coarse.shape[-1] * 2)


def lift3d_forward_ref(x):
    """Separable 3-D forward lift: one step along each axis.

    Returns the full same-shape array `y` whose [:d,:d,:d] octant is the
    coarse approximation and the remaining 7 octants are detail subbands
    (d = D/2). Axis order: last axis first, then middle, then first.
    """
    D = x.shape[0]
    assert x.shape == (D, D, D) and D % 2 == 0
    # Axis 2.
    c, d = lift_forward_ref(x)
    y = jnp.concatenate([c, d], axis=2)
    # Axis 1.
    y = jnp.swapaxes(y, 1, 2)
    c, d = lift_forward_ref(y)
    y = jnp.concatenate([c, d], axis=2)
    y = jnp.swapaxes(y, 1, 2)
    # Axis 0.
    y = jnp.swapaxes(y, 0, 2)
    c, d = lift_forward_ref(y)
    y = jnp.concatenate([c, d], axis=2)
    y = jnp.swapaxes(y, 0, 2)
    return y


def lift3d_inverse_ref(y):
    """Inverse of :func:`lift3d_forward_ref`."""
    D = y.shape[0]
    h = D // 2
    # Axis 0.
    z = jnp.swapaxes(y, 0, 2)
    z = lift_inverse_ref(z[..., :h], z[..., h:])
    z = jnp.swapaxes(z, 0, 2)
    # Axis 1.
    z = jnp.swapaxes(z, 1, 2)
    z = lift_inverse_ref(z[..., :h], z[..., h:])
    z = jnp.swapaxes(z, 1, 2)
    # Axis 2.
    return lift_inverse_ref(z[..., :h], z[..., h:])


def detail_octants(y):
    """Flatten the 7 detail octants of a lifted cube (fixed order)."""
    h = y.shape[0] // 2
    parts = []
    for oi in range(2):
        for oj in range(2):
            for ok in range(2):
                if (oi, oj, ok) == (0, 0, 0):
                    continue
                parts.append(
                    y[
                        oi * h : (oi + 1) * h,
                        oj * h : (oj + 1) * h,
                        ok * h : (ok + 1) * h,
                    ].reshape(-1)
                )
    return jnp.concatenate(parts)


def unflatten_octants(coarse, det_flat):
    """Rebuild the full lifted cube from coarse octant + flat details."""
    h = coarse.shape[0]
    D = 2 * h
    y = jnp.zeros((D, D, D), dtype=coarse.dtype)
    y = y.at[:h, :h, :h].set(coarse)
    idx = 0
    csize = h * h * h
    for oi in range(2):
        for oj in range(2):
            for ok in range(2):
                if (oi, oj, ok) == (0, 0, 0):
                    continue
                block = det_flat[idx * csize : (idx + 1) * csize].reshape(h, h, h)
                y = y.at[
                    oi * h : (oi + 1) * h,
                    oj * h : (oj + 1) * h,
                    ok * h : (ok + 1) * h,
                ].set(block)
                idx += 1
    return y


def decompose_ref(x, levels):
    """Multilevel decomposition into `levels` flattened buffers.

    level 1 (index 0) is the coarsest approximation cube; level i>1 holds
    the 7 detail octants at scale D/2^(levels-i+1), flattened. Matches the
    paper's hierarchy: more levels => lower reconstruction error.
    """
    D = x.shape[0]
    assert D % (1 << (levels - 1)) == 0, "D must be divisible by 2^(L-1)"
    details = []
    cur = x
    for _ in range(levels - 1):
        y = lift3d_forward_ref(cur)
        h = cur.shape[0] // 2
        coarse = y[:h, :h, :h]
        details.append(detail_octants(y))
        cur = coarse
    out = [cur.reshape(-1)]
    out.extend(reversed(details))
    return out


def reconstruct_ref(level_buffers, levels_used, total_levels, D):
    """Progressive reconstruction from the first `levels_used` buffers.

    Missing detail levels are treated as zero (pure upsampling via the
    inverse predictor), mirroring the paper's progressive retrieval.
    """
    base = D >> (total_levels - 1)
    cur = level_buffers[0].reshape(base, base, base)
    for i in range(1, total_levels):
        h = cur.shape[0]
        if i < levels_used:
            det = level_buffers[i]
        else:
            det = jnp.zeros(7 * h * h * h, dtype=cur.dtype)
        y = unflatten_octants(cur, det)
        cur = lift3d_inverse_ref(y)
    return cur


def linf_rel_error_ref(original, approx):
    """Relative L-infinity error, Eq. 1 of the paper."""
    num = jnp.max(jnp.abs(original - approx))
    den = jnp.max(jnp.abs(original))
    return num / den
