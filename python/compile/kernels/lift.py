"""Layer-1 Pallas kernels: the lifting step of the multilevel refactorer.

The compute hot-spot of the refactoring pipeline is the per-axis lifting
(predict/split) pass over the whole volume. Each kernel processes a
(BLOCK_ROWS, W) tile of the flattened (rows, W) view of the volume:
one HBM read of the fine data, one write each of coarse and detail --
the minimum possible traffic for this memory-bound transform (see
DESIGN.md section "Hardware-Adaptation" for the TPU mapping: tiles sized
for VMEM, stencil on the VPU, BlockSpec expressing the HBM<->VMEM
schedule).

All pallas_calls use interpret=True: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and correctness (vs kernels/ref.py) is the contract
on this backend.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows per grid step. 8 x 4096 f32 in + 2 x (8 x 2048) out = 256 KiB of
# VMEM traffic per step -- far under the ~16 MiB budget, leaving room for
# double buffering.
BLOCK_ROWS = 8


def _fwd_kernel(x_ref, c_ref, d_ref):
    x = x_ref[...]
    even = x[:, 0::2]
    odd = x[:, 1::2]
    right = jnp.concatenate([even[:, 1:], even[:, -1:]], axis=1)
    detail = odd - 0.5 * (even + right)
    dleft = jnp.concatenate([detail[:, :1], detail[:, :-1]], axis=1)
    c_ref[...] = even + 0.25 * (dleft + detail)
    d_ref[...] = detail


def _inv_kernel(c_ref, d_ref, x_ref):
    coarse = c_ref[...]
    det = d_ref[...]
    dleft = jnp.concatenate([det[:, :1], det[:, :-1]], axis=1)
    even = coarse - 0.25 * (dleft + det)
    right = jnp.concatenate([even[:, 1:], even[:, -1:]], axis=1)
    odd = det + 0.5 * (even + right)
    x = jnp.stack([even, odd], axis=-1).reshape(even.shape[0], even.shape[1] * 2)
    x_ref[...] = x


def _grid(rows, block):
    assert rows % block == 0, f"rows {rows} not divisible by block {block}"
    return rows // block


@functools.partial(jax.jit, static_argnames=("block_rows",))
def lift_forward(x, block_rows=BLOCK_ROWS):
    """Forward lifting along the last axis of a 2-D view.

    x: (rows, W) with W even. Returns (coarse, detail), each (rows, W/2).
    """
    rows, w = x.shape
    assert w % 2 == 0
    block = min(block_rows, rows)
    grid = _grid(rows, block)
    half = w // 2
    return pl.pallas_call(
        _fwd_kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec((block, w), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block, half), lambda i: (i, 0)),
            pl.BlockSpec((block, half), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, half), x.dtype),
            jax.ShapeDtypeStruct((rows, half), x.dtype),
        ],
        interpret=True,
    )(x)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def lift_inverse(coarse, detail, block_rows=BLOCK_ROWS):
    """Inverse lifting: (rows, W/2) x 2 -> (rows, W)."""
    rows, half = coarse.shape
    assert detail.shape == (rows, half)
    block = min(block_rows, rows)
    grid = _grid(rows, block)
    return pl.pallas_call(
        _inv_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((block, half), lambda i: (i, 0)),
            pl.BlockSpec((block, half), lambda i: (i, 0)),
        ],
        out_specs=[pl.BlockSpec((block, half * 2), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((rows, half * 2), coarse.dtype)],
        interpret=True,
    )(coarse, detail)[0]
