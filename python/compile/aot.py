"""AOT lowering: JAX model -> HLO text artifacts for the Rust runtime.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(what the published `xla` 0.1.6 crate links) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Artifacts (for volume dimension D and L levels):
  refactor_d{D}_l{L}.hlo.txt          x:(D,D,D) -> (level_1..level_L)
  reconstruct_d{D}_l{L}_u{u}.hlo.txt  (level_1..level_u) -> x_hat:(D,D,D)
  linf_error_d{D}.hlo.txt             (a, b) -> scalar relative L-inf err
  manifest.tsv                        name, file, input arity/shapes

Run once via `make artifacts`; never imported at runtime.
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export(out_dir: str, dim: int, levels: int, verbose: bool = True):
    os.makedirs(out_dir, exist_ok=True)
    vol = jax.ShapeDtypeStruct((dim, dim, dim), jnp.float32)
    sizes = model.level_sizes(dim, levels)
    elems = [s // 4 for s in sizes]
    manifest = []

    def emit(name, fn, args):
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        shapes = ";".join(
            "x".join(str(d) for d in a.shape) or "scalar" for a in args
        )
        manifest.append((name, fname, len(args), shapes))
        if verbose:
            print(f"  {fname}: {len(text)} chars, inputs [{shapes}]")

    # Refactor: volume -> L level buffers.
    emit(
        f"refactor_d{dim}_l{levels}",
        lambda x: model.refactor(x, levels),
        (vol,),
    )

    # Progressive reconstruction for every usable prefix length.
    for used in range(1, levels + 1):
        specs = tuple(
            jax.ShapeDtypeStruct((elems[i],), jnp.float32) for i in range(used)
        )

        def recon(*bufs, _used=used):
            return (model.reconstruct(list(bufs), _used, levels, dim),)

        emit(f"reconstruct_d{dim}_l{levels}_u{used}", recon, specs)

    # Error metric.
    emit(
        f"linf_error_d{dim}",
        lambda a, b: (model.linf_rel_error(a, b),),
        (vol, vol),
    )

    with open(os.path.join(out_dir, "manifest.tsv"), "w") as f:
        f.write(f"# dim={dim} levels={levels}\n")
        for name, fname, arity, shapes in manifest:
            f.write(f"{name}\t{fname}\t{arity}\t{shapes}\n")
    if verbose:
        print(f"wrote {len(manifest)} artifacts + manifest.tsv to {out_dir}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--levels", type=int, default=4)
    args = ap.parse_args()
    export(args.out_dir, args.dim, args.levels)


if __name__ == "__main__":
    main()
