"""Layer-2 JAX model: the multilevel refactoring pipeline.

Composes the Layer-1 Pallas lifting kernels into the 3-D separable
multilevel decomposition / progressive reconstruction used by the Janus
endpoints (the pMGARD substitute, DESIGN.md section 3), plus the relative
L-infinity error metric (paper Eq. 1).

These functions are lowered ONCE to HLO text by aot.py; the Rust
coordinator loads and executes the artifacts via PJRT. Python never runs
on the request path.
"""

import jax
import jax.numpy as jnp

from .kernels.lift import lift_forward, lift_inverse
from .kernels.ref import detail_octants, unflatten_octants


def _lift3d(x):
    """One separable 3-D lift step via the Pallas 1-D kernels."""

    def along_last(a):
        rows = a.shape[0] * a.shape[1]
        half = a.shape[2] // 2
        c, d = lift_forward(a.reshape(rows, a.shape[2]))
        c = c.reshape(a.shape[0], a.shape[1], half)
        d = d.reshape(a.shape[0], a.shape[1], half)
        return jnp.concatenate([c, d], axis=2)

    y = along_last(x)
    y = jnp.swapaxes(along_last(jnp.swapaxes(y, 1, 2)), 1, 2)
    y = jnp.swapaxes(along_last(jnp.swapaxes(y, 0, 2)), 0, 2)
    return y


def _unlift3d(y):
    """Inverse of :func:`_lift3d` via the Pallas inverse kernel."""

    def inv_last(a):
        rows = a.shape[0] * a.shape[1]
        half = a.shape[2] // 2
        c = a[:, :, :half].reshape(rows, half)
        d = a[:, :, half:].reshape(rows, half)
        x = lift_inverse(c, d)
        return x.reshape(a.shape[0], a.shape[1], a.shape[2])

    z = jnp.swapaxes(inv_last(jnp.swapaxes(y, 0, 2)), 0, 2)
    z = jnp.swapaxes(inv_last(jnp.swapaxes(z, 1, 2)), 1, 2)
    return inv_last(z)


def refactor(x, levels):
    """Decompose a (D, D, D) volume into `levels` flat buffers.

    Returns a tuple: (level_1, ..., level_L) where level 1 is the coarsest
    approximation and later levels add finer detail octants.
    """
    details = []
    cur = x
    for _ in range(levels - 1):
        y = _lift3d(cur)
        h = cur.shape[0] // 2
        details.append(detail_octants(y))
        cur = y[:h, :h, :h]
    out = [cur.reshape(-1)]
    out.extend(reversed(details))
    return tuple(out)


def reconstruct(level_buffers, levels_used, total_levels, D):
    """Progressive reconstruction from the first `levels_used` buffers.

    Missing detail levels are zero-filled (smooth upsampling through the
    inverse predictor).
    """
    base = D >> (total_levels - 1)
    cur = level_buffers[0].reshape(base, base, base)
    for i in range(1, total_levels):
        h = cur.shape[0]
        if i < levels_used:
            det = level_buffers[i]
        else:
            det = jnp.zeros(7 * h * h * h, dtype=cur.dtype)
        cur = _unlift3d(unflatten_octants(cur, det))
    return cur


def linf_rel_error(original, approx):
    """Relative L-infinity error (paper Eq. 1)."""
    return jnp.max(jnp.abs(original - approx)) / jnp.max(jnp.abs(original))


def level_sizes(D, levels):
    """Float32 byte size of each level buffer for a (D, D, D) volume."""
    base = D >> (levels - 1)
    sizes = [base**3 * 4]
    h = base
    for _ in range(1, levels):
        sizes.append(7 * h**3 * 4)
        h *= 2
    return sizes
